//! Bit-true quantized inference engine (the paper's "PyTorch-based
//! simulation framework that accurately reflects bitwise operations of
//! CiM", §6.1 — re-implemented in rust).
//!
//! The engine interprets the model IR over per-image CHW `u8`
//! activations. Convolutions/linears run through a [`MacBackend`]: the
//! exact backend computes the integer GEMM directly; the PAC backend
//! (`nn::pac_exec`) replays the hybrid digital/sparsity computation of
//! the PACiM bank. Everything around the MACs (im2col, requantization,
//! pooling, residual adds) is shared, so accuracy differences between
//! engines isolate the approximation itself.

use super::layers::{ConvLayer, Model, Op};
use crate::arch::LevelHistogram;
use crate::tensor::{im2col, QuantParams, Tensor};
use crate::util::Parallelism;

/// Per-run statistics (accuracy benches aggregate these across images).
#[derive(Debug, Clone, Default)]
pub struct RunStats {
    /// Total MACs executed.
    pub macs: u64,
    /// Digital bit-serial cycles (per output MAC, summed).
    pub digital_cycles: u64,
    /// PCU (sparsity-domain) ops.
    pub pcu_ops: u64,
    /// Dynamic-level decisions (empty when dynamic config is off).
    pub levels: LevelHistogram,
}

impl RunStats {
    pub fn merge(&mut self, other: &RunStats) {
        self.macs += other.macs;
        self.digital_cycles += other.digital_cycles;
        self.pcu_ops += other.pcu_ops;
        self.levels.merge(&other.levels);
    }

    /// Average digital cycles per 8b/8b MAC (64 would be fully digital).
    pub fn avg_cycles_per_mac(&self) -> f64 {
        if self.macs == 0 {
            return 0.0;
        }
        self.digital_cycles as f64 / self.macs as f64
    }
}

/// Backend computing signed accumulators `Σ_k (x−zpx)(w−zpw)` for every
/// output channel of one im2col patch.
pub trait MacBackend {
    /// Called once per compute layer in program order; `layer_id` indexes
    /// subsequent `gemm` calls.
    fn prepare(&mut self, layer_id: usize, weight: &Tensor<u8>, zpw: i32);

    /// Accumulators for one patch (length = weight rows).
    fn gemm(&self, layer_id: usize, patch: &[u8], zpx: i32, stats: &mut RunStats) -> Vec<i64>;
}

/// Exact integer backend (the 8-bit QAT/PTQ reference).
#[derive(Default)]
pub struct ExactBackend {
    /// Per layer: (weights [n, k] as i32-ready u8, zpw, k).
    layers: Vec<(Tensor<u8>, i32)>,
}

impl MacBackend for ExactBackend {
    fn prepare(&mut self, layer_id: usize, weight: &Tensor<u8>, zpw: i32) {
        assert_eq!(layer_id, self.layers.len(), "layers must prepare in order");
        self.layers.push((weight.clone(), zpw));
    }

    fn gemm(&self, layer_id: usize, patch: &[u8], zpx: i32, stats: &mut RunStats) -> Vec<i64> {
        let (w, zpw) = &self.layers[layer_id];
        let k = patch.len();
        let n = w.shape()[0];
        debug_assert_eq!(w.shape()[1], k);
        let wd = w.data();
        let mut out = Vec::with_capacity(n);
        for oc in 0..n {
            let row = &wd[oc * k..(oc + 1) * k];
            let mut acc = 0i64;
            for (&x, &wv) in patch.iter().zip(row) {
                acc += (x as i64 - zpx as i64) * (wv as i64 - *zpw as i64);
            }
            out.push(acc);
        }
        stats.macs += (n * k) as u64;
        stats.digital_cycles += (n as u64) * 64; // 8b/8b fully digital
        out
    }
}

/// The shared interpreter. Runs `model` on one quantized CHW image with
/// every layer loop scalar (the deterministic reference path).
pub fn run_model<B: MacBackend + Sync>(
    model: &Model,
    backend: &B,
    image: &[u8],
) -> (Vec<f32>, RunStats) {
    run_model_par(model, backend, image, &Parallelism::off())
}

/// The shared interpreter with an explicit parallelism policy: each
/// convolution's output pixels (one im2col patch each — the DP columns of
/// the CiM array) are fanned out over rayon when `par` allows it.
///
/// Bit-identical to [`run_model`] for any `par`: patches are independent,
/// per-patch statistics are integer counters merged in pixel order, and
/// outputs are written by index.
pub fn run_model_par<B: MacBackend + Sync>(
    model: &Model,
    backend: &B,
    image: &[u8],
    par: &Parallelism,
) -> (Vec<f32>, RunStats) {
    assert_eq!(
        image.len(),
        model.in_c * model.in_hw * model.in_hw,
        "input size mismatch"
    );
    let mut stats = RunStats::default();
    let mut act = image.to_vec();
    let mut params = model.input_params;
    let mut shape = (model.in_c, model.in_hw, model.in_hw);
    let mut skips: Vec<(Vec<u8>, QuantParams, (usize, usize, usize))> = Vec::new();
    let mut layer_id = 0usize;
    let mut logits: Option<Vec<f32>> = None;

    for op in &model.ops {
        match op {
            Op::Conv2d(conv) => {
                let (out, op_params, oshape) =
                    run_conv(conv, &act, params, layer_id, backend, &mut stats, par);
                act = out;
                params = op_params;
                shape = oshape;
                layer_id += 1;
            }
            Op::Linear(lin) => {
                let (c, h, w) = shape;
                assert_eq!(c * h * w, lin.in_f, "linear input mismatch at {}", lin.name);
                let accs = backend.gemm(layer_id, &act, params.zero_point, &mut stats);
                layer_id += 1;
                let sx = params.scale;
                let sw = lin.wparams.scale;
                let reals: Vec<f32> = accs
                    .iter()
                    .enumerate()
                    .map(|(i, &a)| a as f32 * sx * sw + lin.bias[i])
                    .collect();
                match &lin.out_params {
                    None => {
                        logits = Some(reals);
                        break;
                    }
                    Some(oq) => {
                        act = reals
                            .iter()
                            .map(|&r| oq.quantize(if lin.relu { r.max(0.0) } else { r }))
                            .collect();
                        params = *oq;
                        shape = (lin.out_f, 1, 1);
                    }
                }
            }
            Op::MaxPool2 => {
                let (c, h, w) = shape;
                let (oh, ow) = (h / 2, w / 2);
                let mut out = vec![0u8; c * oh * ow];
                for ch in 0..c {
                    for y in 0..oh {
                        for x in 0..ow {
                            let mut m = 0u8;
                            for dy in 0..2 {
                                for dx in 0..2 {
                                    m = m.max(act[(ch * h + 2 * y + dy) * w + 2 * x + dx]);
                                }
                            }
                            out[(ch * oh + y) * ow + x] = m;
                        }
                    }
                }
                act = out;
                shape = (c, oh, ow);
            }
            Op::GlobalAvgPool => {
                let (c, h, w) = shape;
                let px = h * w;
                let mut out = vec![0u8; c];
                for ch in 0..c {
                    let sum: u32 = act[ch * px..(ch + 1) * px].iter().map(|&v| v as u32).sum();
                    out[ch] = ((sum + px as u32 / 2) / px as u32) as u8;
                }
                act = out;
                shape = (c, 1, 1);
            }
            Op::SaveSkip => {
                skips.push((act.clone(), params, shape));
            }
            Op::AddSkip { out_params, relu } => {
                let (skip, skip_params, skip_shape) =
                    skips.pop().expect("AddSkip without SaveSkip");
                assert_eq!(skip_shape, shape, "skip shape mismatch");
                act = act
                    .iter()
                    .zip(&skip)
                    .map(|(&a, &b)| {
                        let r = params.dequantize(a) + skip_params.dequantize(b);
                        out_params.quantize(if *relu { r.max(0.0) } else { r })
                    })
                    .collect();
                params = *out_params;
            }
        }
    }
    (
        logits.expect("model did not end in a logits layer"),
        stats,
    )
}

/// Run a batch of images through the interpreter, fanning the *lanes*
/// out over rayon (the intra-batch parallelism of the serving path:
/// each lane is one whole forward pass, so the fan-out threshold is
/// coarse — see [`Parallelism::coarse`]).
///
/// Bit-identical to looping [`run_model`] over `images`: lanes are
/// independent and collected in lane order.
pub fn run_model_batch<B: MacBackend + Sync>(
    model: &Model,
    backend: &B,
    images: &[&[u8]],
    par: &Parallelism,
) -> Vec<(Vec<f32>, RunStats)> {
    par.map_collect(images.len(), |lane| run_model(model, backend, images[lane]))
}

fn run_conv<B: MacBackend + Sync>(
    conv: &ConvLayer,
    act: &[u8],
    in_params: QuantParams,
    layer_id: usize,
    backend: &B,
    stats: &mut RunStats,
    par: &Parallelism,
) -> (Vec<u8>, QuantParams, (usize, usize, usize)) {
    let g = &conv.geom;
    let cols = im2col(act, g, in_params.zero_point as u8);
    let k = g.dp_len();
    let pixels = g.out_pixels();
    let sx = in_params.scale;
    let sw = conv.wparams.scale;
    // Output is CHW: out[oc][pixel].
    let mut out = vec![0u8; g.out_c * pixels];
    let requant = |accs: &[i64], pix: usize, out: &mut [u8]| {
        for (oc, &acc) in accs.iter().enumerate() {
            let real = acc as f32 * sx * sw + conv.bias[oc];
            let real = if conv.relu { real.max(0.0) } else { real };
            out[oc * pixels + pix] = conv.out_params.quantize(real);
        }
    };
    if par.should_parallelize(pixels) {
        // Work-stolen across output pixels; each task carries its own
        // RunStats which are merged back in pixel order (integer
        // counters, so the merge is exact regardless of schedule).
        let results: Vec<(Vec<i64>, RunStats)> = par.map_collect(pixels, |pix| {
            let mut local = RunStats::default();
            let patch = &cols[pix * k..(pix + 1) * k];
            let accs = backend.gemm(layer_id, patch, in_params.zero_point, &mut local);
            (accs, local)
        });
        for (pix, (accs, local)) in results.into_iter().enumerate() {
            stats.merge(&local);
            requant(&accs, pix, &mut out);
        }
    } else {
        // Scalar path streams one patch at a time — no per-pixel
        // accumulator buffering, stats written directly.
        for pix in 0..pixels {
            let patch = &cols[pix * k..(pix + 1) * k];
            let accs = backend.gemm(layer_id, patch, in_params.zero_point, stats);
            requant(&accs, pix, &mut out);
        }
    }
    (
        out,
        conv.out_params,
        (g.out_c, g.out_h(), g.out_w()),
    )
}

/// Convenience: build an exact backend prepared for `model`.
pub fn exact_backend(model: &Model) -> ExactBackend {
    let mut b = ExactBackend::default();
    let mut id = 0;
    for op in &model.ops {
        match op {
            Op::Conv2d(c) => {
                b.prepare(id, &c.weight, c.wparams.zero_point);
                id += 1;
            }
            Op::Linear(l) => {
                b.prepare(id, &l.weight, l.wparams.zero_point);
                id += 1;
            }
            _ => {}
        }
    }
    b
}

/// Run a whole dataset slice and return top-1 accuracy.
pub fn evaluate<B: MacBackend + Sync>(
    model: &Model,
    backend: &B,
    images: &[&[u8]],
    labels: &[usize],
    threads: usize,
) -> (f64, RunStats) {
    assert_eq!(images.len(), labels.len());
    let n = images.len();
    let correct = std::sync::atomic::AtomicUsize::new(0);
    let all_stats = std::sync::Mutex::new(RunStats::default());
    let next = std::sync::atomic::AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads.max(1) {
            s.spawn(|| {
                let mut local = RunStats::default();
                loop {
                    let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let (logits, st) = run_model(model, backend, images[i]);
                    local.merge(&st);
                    let pred = logits
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .map(|(i, _)| i)
                        .unwrap();
                    if pred == labels[i] {
                        correct.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    }
                }
                all_stats.lock().unwrap().merge(&local);
            });
        }
    });
    let acc = correct.load(std::sync::atomic::Ordering::Relaxed) as f64 / n.max(1) as f64;
    (acc, all_stats.into_inner().unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::layers::{synthetic, tiny_resnet};
    use crate::util::rng::Rng;

    #[test]
    fn exact_engine_runs_tiny_resnet() {
        let mut rng = Rng::new(200);
        let store = synthetic::random_store(&mut rng, 8, 10);
        let model = tiny_resnet(&store, 16, 10).unwrap();
        let backend = exact_backend(&model);
        let img: Vec<u8> = (0..3 * 16 * 16).map(|_| rng.below(256) as u8).collect();
        let (logits, stats) = run_model(&model, &backend, &img);
        assert_eq!(logits.len(), 10);
        assert!(logits.iter().all(|l| l.is_finite()));
        assert_eq!(stats.macs, model.macs());
    }

    #[test]
    fn deterministic_across_runs() {
        let mut rng = Rng::new(201);
        let store = synthetic::random_store(&mut rng, 8, 10);
        let model = tiny_resnet(&store, 16, 10).unwrap();
        let backend = exact_backend(&model);
        let img: Vec<u8> = (0..3 * 16 * 16).map(|_| rng.below(256) as u8).collect();
        let (a, _) = run_model(&model, &backend, &img);
        let (b, _) = run_model(&model, &backend, &img);
        assert_eq!(a, b);
    }

    #[test]
    fn different_images_different_logits() {
        let mut rng = Rng::new(202);
        let store = synthetic::random_store(&mut rng, 8, 10);
        let model = tiny_resnet(&store, 16, 10).unwrap();
        let backend = exact_backend(&model);
        let img1: Vec<u8> = (0..3 * 16 * 16).map(|_| rng.below(256) as u8).collect();
        let img2: Vec<u8> = (0..3 * 16 * 16).map(|_| rng.below(256) as u8).collect();
        let (a, _) = run_model(&model, &backend, &img1);
        let (b, _) = run_model(&model, &backend, &img2);
        assert_ne!(a, b);
    }

    #[test]
    fn parallel_run_bit_identical_to_scalar() {
        // The rayon pixel fan-out must not change a single bit of the
        // logits or the statistics, at any threshold.
        let mut rng = Rng::new(210);
        let store = synthetic::random_store(&mut rng, 8, 10);
        let model = tiny_resnet(&store, 16, 10).unwrap();
        let backend = exact_backend(&model);
        let img: Vec<u8> = (0..3 * 16 * 16).map(|_| rng.below(256) as u8).collect();
        let (a, sa) = run_model(&model, &backend, &img);
        for par in [
            Parallelism::auto(),
            Parallelism {
                enabled: true,
                min_items: 1,
            },
        ] {
            let (b, sb) = run_model_par(&model, &backend, &img, &par);
            assert_eq!(a, b);
            assert_eq!(sa.macs, sb.macs);
            assert_eq!(sa.digital_cycles, sb.digital_cycles);
            assert_eq!(sa.pcu_ops, sb.pcu_ops);
            assert_eq!(sa.levels, sb.levels);
        }
    }

    #[test]
    fn batch_run_bit_identical_to_sequential() {
        let mut rng = Rng::new(211);
        let store = synthetic::random_store(&mut rng, 8, 10);
        let model = tiny_resnet(&store, 16, 10).unwrap();
        let backend = exact_backend(&model);
        let imgs: Vec<Vec<u8>> = (0..5)
            .map(|_| (0..3 * 16 * 16).map(|_| rng.below(256) as u8).collect())
            .collect();
        let refs: Vec<&[u8]> = imgs.iter().map(|v| v.as_slice()).collect();
        let seq: Vec<(Vec<f32>, RunStats)> = refs
            .iter()
            .map(|img| run_model(&model, &backend, img))
            .collect();
        for par in [Parallelism::off(), Parallelism::coarse()] {
            let lanes = run_model_batch(&model, &backend, &refs, &par);
            for ((a, sa), (b, sb)) in seq.iter().zip(&lanes) {
                assert_eq!(a, b);
                assert_eq!(sa.macs, sb.macs);
            }
        }
    }

    #[test]
    fn evaluate_counts_accuracy() {
        let mut rng = Rng::new(203);
        let store = synthetic::random_store(&mut rng, 8, 4);
        let model = tiny_resnet(&store, 16, 4).unwrap();
        let backend = exact_backend(&model);
        let imgs: Vec<Vec<u8>> = (0..8)
            .map(|_| (0..3 * 16 * 16).map(|_| rng.below(256) as u8).collect())
            .collect();
        let refs: Vec<&[u8]> = imgs.iter().map(|v| v.as_slice()).collect();
        // Label each image by the model's own prediction → accuracy 1.0.
        let labels: Vec<usize> = refs
            .iter()
            .map(|img| {
                let (lg, _) = run_model(&model, &backend, img);
                lg.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0
            })
            .collect();
        let (acc, stats) = evaluate(&model, &backend, &refs, &labels, 4);
        assert_eq!(acc, 1.0);
        assert_eq!(stats.macs, model.macs() * 8);
    }

    #[test]
    fn maxpool_and_gap_shapes() {
        // Covered implicitly by tiny_vgg when artifacts exist; here check
        // the pure ops via a crafted mini-program.
        use crate::nn::layers::{LinearLayer, Model, Op};
        use crate::tensor::{QuantParams, Tensor};
        let ident = QuantParams::new(1.0, 0);
        let lin = LinearLayer {
            name: "fc".into(),
            in_f: 1,
            out_f: 2,
            weight: Tensor::from_vec(&[2, 1], vec![1u8, 3]),
            wparams: QuantParams::new(1.0, 0),
            bias: vec![0.0, 0.0],
            out_params: None,
            relu: false,
        };
        let model = Model {
            name: "mini".into(),
            ops: vec![Op::MaxPool2, Op::GlobalAvgPool, Op::Linear(lin)],
            input_params: ident,
            in_c: 1,
            in_hw: 4,
            num_classes: 2,
        };
        let mut backend = ExactBackend::default();
        if let Op::Linear(l) = &model.ops[2] {
            backend.prepare(0, &l.weight, 0);
        }
        // 4×4 image; maxpool → 2×2 of maxes; GAP → mean.
        let img = vec![
            1u8, 2, 3, 4, //
            5, 6, 7, 8, //
            9, 10, 11, 12, //
            13, 14, 15, 16,
        ];
        let (logits, _) = run_model(&model, &backend, &img);
        // maxes = [6, 8, 14, 16] → mean 11 → logits [11, 33].
        assert_eq!(logits, vec![11.0, 33.0]);
    }
}
