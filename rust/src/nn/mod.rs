//! Bit-true quantized neural-network engine.
//!
//! - [`layers`] — quantized model IR + tiny-model builders (topologies
//!   shared with `python/compile/model.py`)
//! - [`weights`] — the `weights.bin` artifact format
//! - [`exec`] — the shared interpreter + exact integer backend
//! - [`pac_exec`] — the PAC hybrid backend (the paper's approximation)
//! - [`simd`] — the tiered popcount sweeps (scalar/AVX2/AVX-512) the
//!   PAC backend's blocked GEMM dispatches into
//!
//! Accuracy experiments (Fig. 6, Table 2) run the same trained model
//! through both backends and diff the top-1 accuracy.
//!
//! Construct inference through [`crate::engine`] (the typed Session
//! front door); the free functions re-exported here are the low-level
//! reference path (`run_model_with`, `run_model_batch_with`).

pub mod exec;
pub mod layers;
pub mod pac_exec;
pub mod profiler;
pub mod simd;
pub mod weights;

pub use exec::{
    exact_backend, run_model_batch_with, run_model_with, ExactBackend, GemmInput, MacBackend,
    ModelScratch, RunStats,
};
pub use layers::{tiny_resnet, tiny_vgg, ConvLayer, LinearLayer, Model, Op};
pub use pac_exec::{pac_backend, EscalationConfig, PacBackend, PacConfig};
pub use profiler::{LayerProfile, ProfilingBackend};
pub use weights::{DType, Entry, WeightStore};
