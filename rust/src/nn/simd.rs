//! SIMD-native popcount sweeps for the blocked bit-plane GEMM.
//!
//! A PACiM digital cycle is `popcount(x_plane & w_plane)` over the DP
//! vector's packed `u64` words. The static-4×4 tile kernels in
//! [`super::pac_exec`] spend essentially all of their time in that word
//! sweep, always over the **four weight MSB planes** (`q ∈ 4..8`) of one
//! output column, which the prepared layout stores contiguously. This
//! module owns that sweep in three bit-identical tiers, dispatched by a
//! clamped [`KernelCaps`] (see `util::kernel` and DESIGN.md §13):
//!
//! - [`sweep4_scalar`] — the portable reference: one pass over the
//!   words, four `u64::count_ones` per word. This is the *single* scalar
//!   word sweep in the crate; the per-patch reference kernel and the
//!   blocked tile kernels both call it.
//! - AVX2 — 4-word (256-bit) blocks, popcount via the classic 4-bit
//!   nibble lookup (`_mm256_shuffle_epi8`) reduced with
//!   `_mm256_sad_epu8` into per-lane `u64` accumulators.
//! - AVX-512 (nightly-only `avx512` cargo feature) — 8-word blocks
//!   using the native `VPOPCNTQ` (`_mm512_popcnt_epi64`).
//!
//! **Weight-plane zero-skipping.** Each sweep optionally takes a
//! per-column *live-word bitmap* (`skip`): bit `i` set means word `i`
//! is nonzero in at least one of the column's four MSB weight planes.
//! Words whose bit is clear contribute `x & 0 = 0` to every counter, so
//! skipping them is exact, not approximate. The scalar tier iterates
//! set bits (`trailing_zeros`); the vector tiers test whole blocks (a
//! nibble/byte of the bitmap) and skip only fully-dead blocks. Columns
//! too dense to profit opt out at prepare time (the density auto-off
//! rule in `pac_exec`), in which case `skip` is `None` here.
//!
//! Every function in this module returns identical integers across
//! tiers and across `skip` on/off; the property tests in
//! `tests/proptests.rs` and the unit tests below pin that.

use crate::util::{KernelCaps, KernelTier};

/// Fold a 4-counter sweep result into the raw accumulator for
/// activation plane `p`: counter `c[j]` (weight plane `q = 4 + j`)
/// contributes `c[j] << (p + 4 + j)` — the bit-serial shift-add of
/// Eq. 1 restricted to the 4×4 MSB block.
#[inline]
pub fn fold4(c: [u32; 4], p: usize) -> i64 {
    ((c[0] as i64) << (p + 4))
        + ((c[1] as i64) << (p + 5))
        + ((c[2] as i64) << (p + 6))
        + ((c[3] as i64) << (p + 7))
}

/// AND-popcount of one activation plane `x` against a column's four
/// contiguous MSB weight planes `wmsb` (`wmsb.len() == 4 * x.len()`,
/// planes `q = 4..8` back to back), dispatched by tier. Returns the
/// four popcount counters `[c4, c5, c6, c7]`.
///
/// `skip`, when present, is the column's live-word bitmap
/// (`skip.len() == x.len().div_ceil(64)`); dead words are skipped
/// exactly (they contribute nothing to any counter).
#[inline]
pub fn sweep4(caps: KernelCaps, x: &[u64], wmsb: &[u64], skip: Option<&[u64]>) -> [u32; 4] {
    debug_assert_eq!(wmsb.len(), 4 * x.len());
    match caps.tier() {
        KernelTier::Scalar => match skip {
            Some(s) => sweep4_scalar_skip(x, wmsb, s),
            None => sweep4_scalar(x, wmsb),
        },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `caps.tier()` can only report Avx2 when the CPUID
        // probe confirmed AVX2 (KernelCaps clamps every request; its
        // fields are private, so no unclamped value exists).
        KernelTier::Avx2 => unsafe { avx2::sweep4(x, wmsb, skip) },
        #[cfg(all(target_arch = "x86_64", feature = "avx512"))]
        // SAFETY: as above — Avx512 is only reachable when the probe
        // confirmed AVX-512F + VPOPCNTDQ (and the feature compiled it).
        KernelTier::Avx512 => unsafe { avx512::sweep4(x, wmsb, skip) },
        // Unreachable in practice (KernelCaps never resolves a tier the
        // build can't run); keep a portable fallback rather than a panic.
        #[allow(unreachable_patterns)]
        _ => match skip {
            Some(s) => sweep4_scalar_skip(x, wmsb, s),
            None => sweep4_scalar(x, wmsb),
        },
    }
}

/// Two-pixel variant of [`sweep4`]: sweep activation planes `x0` and
/// `x1` against the same four MSB weight planes in one pass, so each
/// weight-word load feeds both pixels' popcount lanes (the register
/// tiling of the blocked kernel's pixel-pair inner loop).
#[inline]
pub fn sweep4_pair(
    caps: KernelCaps,
    x0: &[u64],
    x1: &[u64],
    wmsb: &[u64],
    skip: Option<&[u64]>,
) -> [[u32; 4]; 2] {
    debug_assert_eq!(x0.len(), x1.len());
    debug_assert_eq!(wmsb.len(), 4 * x0.len());
    match caps.tier() {
        KernelTier::Scalar => match skip {
            Some(s) => sweep4_pair_scalar_skip(x0, x1, wmsb, s),
            None => sweep4_pair_scalar(x0, x1, wmsb),
        },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx2 tier implies the CPUID probe confirmed AVX2.
        KernelTier::Avx2 => unsafe { avx2::sweep4_pair(x0, x1, wmsb, skip) },
        #[cfg(all(target_arch = "x86_64", feature = "avx512"))]
        // SAFETY: Avx512 tier implies AVX-512F + VPOPCNTDQ confirmed.
        KernelTier::Avx512 => unsafe { avx512::sweep4_pair(x0, x1, wmsb, skip) },
        #[allow(unreachable_patterns)]
        _ => match skip {
            Some(s) => sweep4_pair_scalar_skip(x0, x1, wmsb, s),
            None => sweep4_pair_scalar(x0, x1, wmsb),
        },
    }
}

/// Tier-dispatched AND-popcount of two equal-length packed planes —
/// the generic-set kernels' single-plane cycle (`util::and_popcount`
/// is the frozen scalar reference it is tested against).
#[inline]
pub fn and_popcount(caps: KernelCaps, a: &[u64], b: &[u64]) -> u32 {
    debug_assert_eq!(a.len(), b.len());
    match caps.tier() {
        KernelTier::Scalar => crate::util::and_popcount(a, b),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx2 tier implies the CPUID probe confirmed AVX2.
        KernelTier::Avx2 => unsafe { avx2::and_popcount(a, b) },
        #[cfg(all(target_arch = "x86_64", feature = "avx512"))]
        // SAFETY: Avx512 tier implies AVX-512F + VPOPCNTDQ confirmed.
        KernelTier::Avx512 => unsafe { avx512::and_popcount(a, b) },
        #[allow(unreachable_patterns)]
        _ => crate::util::and_popcount(a, b),
    }
}

/// The portable scalar word sweep — the one place the `c4..c7`
/// unrolled loop lives (both the per-patch reference and the blocked
/// kernels' scalar tier call this).
#[inline]
pub fn sweep4_scalar(x: &[u64], wmsb: &[u64]) -> [u32; 4] {
    let words = x.len();
    let (w4, rest) = wmsb.split_at(words);
    let (w5, rest) = rest.split_at(words);
    let (w6, w7) = rest.split_at(words);
    let mut c = [0u32; 4];
    for i in 0..words {
        let xv = x[i];
        c[0] += (xv & w4[i]).count_ones();
        c[1] += (xv & w5[i]).count_ones();
        c[2] += (xv & w6[i]).count_ones();
        c[3] += (xv & w7[i]).count_ones();
    }
    c
}

/// Scalar sweep over only the live words named by the bitmap.
fn sweep4_scalar_skip(x: &[u64], wmsb: &[u64], skip: &[u64]) -> [u32; 4] {
    let words = x.len();
    debug_assert_eq!(skip.len(), words.div_ceil(64));
    let (w4, rest) = wmsb.split_at(words);
    let (w5, rest) = rest.split_at(words);
    let (w6, w7) = rest.split_at(words);
    let mut c = [0u32; 4];
    for (sw, &sbits) in skip.iter().enumerate() {
        let mut bits = sbits;
        while bits != 0 {
            let i = sw * 64 + bits.trailing_zeros() as usize;
            bits &= bits - 1;
            let xv = x[i];
            c[0] += (xv & w4[i]).count_ones();
            c[1] += (xv & w5[i]).count_ones();
            c[2] += (xv & w6[i]).count_ones();
            c[3] += (xv & w7[i]).count_ones();
        }
    }
    c
}

/// Scalar pixel-pair sweep (shared weight-word loads).
fn sweep4_pair_scalar(x0: &[u64], x1: &[u64], wmsb: &[u64]) -> [[u32; 4]; 2] {
    let words = x0.len();
    let (w4, rest) = wmsb.split_at(words);
    let (w5, rest) = rest.split_at(words);
    let (w6, w7) = rest.split_at(words);
    let (mut a, mut b) = ([0u32; 4], [0u32; 4]);
    for i in 0..words {
        let (wv4, wv5, wv6, wv7) = (w4[i], w5[i], w6[i], w7[i]);
        let xv0 = x0[i];
        let xv1 = x1[i];
        a[0] += (xv0 & wv4).count_ones();
        b[0] += (xv1 & wv4).count_ones();
        a[1] += (xv0 & wv5).count_ones();
        b[1] += (xv1 & wv5).count_ones();
        a[2] += (xv0 & wv6).count_ones();
        b[2] += (xv1 & wv6).count_ones();
        a[3] += (xv0 & wv7).count_ones();
        b[3] += (xv1 & wv7).count_ones();
    }
    [a, b]
}

/// Scalar pixel-pair sweep over only the live words.
fn sweep4_pair_scalar_skip(x0: &[u64], x1: &[u64], wmsb: &[u64], skip: &[u64]) -> [[u32; 4]; 2] {
    let words = x0.len();
    debug_assert_eq!(skip.len(), words.div_ceil(64));
    let (w4, rest) = wmsb.split_at(words);
    let (w5, rest) = rest.split_at(words);
    let (w6, w7) = rest.split_at(words);
    let (mut a, mut b) = ([0u32; 4], [0u32; 4]);
    for (sw, &sbits) in skip.iter().enumerate() {
        let mut bits = sbits;
        while bits != 0 {
            let i = sw * 64 + bits.trailing_zeros() as usize;
            bits &= bits - 1;
            let (wv4, wv5, wv6, wv7) = (w4[i], w5[i], w6[i], w7[i]);
            let xv0 = x0[i];
            let xv1 = x1[i];
            a[0] += (xv0 & wv4).count_ones();
            b[0] += (xv1 & wv4).count_ones();
            a[1] += (xv0 & wv5).count_ones();
            b[1] += (xv1 & wv5).count_ones();
            a[2] += (xv0 & wv6).count_ones();
            b[2] += (xv1 & wv6).count_ones();
            a[3] += (xv0 & wv7).count_ones();
            b[3] += (xv1 & wv7).count_ones();
        }
    }
    [a, b]
}

/// AVX2 tier: 256-bit AND + nibble-lookup popcount.
///
/// Safety conventions shared by every function in this module (the full
/// argument is DESIGN.md §13.4):
/// - **Feature gating**: every `fn` is `#[target_feature(enable =
///   "avx2")]` and only reachable through a [`KernelCaps`] whose tier
///   was clamped to the CPUID probe, so AVX2 instructions never execute
///   on hardware without them.
/// - **Alignment**: all vector memory access uses unaligned loads
///   (`_mm256_loadu_si256`); slices come from `Vec<u64>` with 8-byte
///   alignment and no further guarantee is needed.
/// - **Bounds**: pointer arithmetic stays inside `blocks * 4 <=
///   words == x.len()` and `q * words + words <= wmsb.len()`, both
///   checked by the `debug_assert_eq!` in the public dispatchers and
///   enforced structurally by the callers (prepared layouts).
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use core::arch::x86_64::*;

    /// Popcount each byte of `v` via the 4-bit nibble lookup, then
    /// horizontally reduce bytes into the four 64-bit lanes
    /// (`_mm256_sad_epu8` against zero). Lane sums fit trivially:
    /// a lane's 8 bytes hold at most 8 × 8 = 64.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn popcnt256(v: __m256i) -> __m256i {
        let lut = _mm256_setr_epi8(
            0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4, //
            0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
        );
        let low_mask = _mm256_set1_epi8(0x0f);
        let lo = _mm256_and_si256(v, low_mask);
        let hi = _mm256_and_si256(_mm256_srli_epi16(v, 4), low_mask);
        let cnt = _mm256_add_epi8(_mm256_shuffle_epi8(lut, lo), _mm256_shuffle_epi8(lut, hi));
        _mm256_sad_epu8(cnt, _mm256_setzero_si256())
    }

    /// Horizontal sum of the four u64 lanes of an accumulator.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn hsum(v: __m256i) -> u64 {
        let mut lanes = [0u64; 4];
        _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, v);
        lanes[0] + lanes[1] + lanes[2] + lanes[3]
    }

    /// AVX2 [`super::sweep4`]: 4-word blocks; with a skip bitmap, a
    /// block is processed only when its 4-bit nibble has a live bit
    /// (block `b` covers words `4b..4b+4`, i.e. bitmap bits `4b..4b+4`,
    /// which never straddle a bitmap word since `4b % 64 <= 60`).
    /// The tail (`words % 4`) always runs scalar.
    #[target_feature(enable = "avx2")]
    pub unsafe fn sweep4(x: &[u64], wmsb: &[u64], skip: Option<&[u64]>) -> [u32; 4] {
        let words = x.len();
        let blocks = words / 4;
        let mut acc = [_mm256_setzero_si256(); 4];
        for b in 0..blocks {
            if let Some(s) = skip {
                let bit = b * 4;
                if (s[bit / 64] >> (bit % 64)) & 0xf == 0 {
                    continue;
                }
            }
            let xv = _mm256_loadu_si256(x.as_ptr().add(b * 4) as *const __m256i);
            for (q, a) in acc.iter_mut().enumerate() {
                let wv =
                    _mm256_loadu_si256(wmsb.as_ptr().add(q * words + b * 4) as *const __m256i);
                *a = _mm256_add_epi64(*a, popcnt256(_mm256_and_si256(xv, wv)));
            }
        }
        let mut c = [0u32; 4];
        for (q, a) in acc.iter().enumerate() {
            c[q] = hsum(*a) as u32;
        }
        for i in blocks * 4..words {
            let xv = x[i];
            for (q, cq) in c.iter_mut().enumerate() {
                *cq += (xv & wmsb[q * words + i]).count_ones();
            }
        }
        c
    }

    /// AVX2 [`super::sweep4_pair`]: same block structure, two pixels'
    /// accumulators fed per weight-block load (8 accumulator registers
    /// + LUT/mask constants still fit the 16 ymm registers).
    #[target_feature(enable = "avx2")]
    pub unsafe fn sweep4_pair(
        x0: &[u64],
        x1: &[u64],
        wmsb: &[u64],
        skip: Option<&[u64]>,
    ) -> [[u32; 4]; 2] {
        let words = x0.len();
        let blocks = words / 4;
        let mut acc0 = [_mm256_setzero_si256(); 4];
        let mut acc1 = [_mm256_setzero_si256(); 4];
        for b in 0..blocks {
            if let Some(s) = skip {
                let bit = b * 4;
                if (s[bit / 64] >> (bit % 64)) & 0xf == 0 {
                    continue;
                }
            }
            let xv0 = _mm256_loadu_si256(x0.as_ptr().add(b * 4) as *const __m256i);
            let xv1 = _mm256_loadu_si256(x1.as_ptr().add(b * 4) as *const __m256i);
            for q in 0..4 {
                let wv =
                    _mm256_loadu_si256(wmsb.as_ptr().add(q * words + b * 4) as *const __m256i);
                acc0[q] = _mm256_add_epi64(acc0[q], popcnt256(_mm256_and_si256(xv0, wv)));
                acc1[q] = _mm256_add_epi64(acc1[q], popcnt256(_mm256_and_si256(xv1, wv)));
            }
        }
        let (mut a, mut b) = ([0u32; 4], [0u32; 4]);
        for q in 0..4 {
            a[q] = hsum(acc0[q]) as u32;
            b[q] = hsum(acc1[q]) as u32;
        }
        for i in blocks * 4..words {
            for q in 0..4 {
                let wv = wmsb[q * words + i];
                a[q] += (x0[i] & wv).count_ones();
                b[q] += (x1[i] & wv).count_ones();
            }
        }
        [a, b]
    }

    /// AVX2 [`super::and_popcount`].
    #[target_feature(enable = "avx2")]
    pub unsafe fn and_popcount(a: &[u64], b: &[u64]) -> u32 {
        let words = a.len();
        let blocks = words / 4;
        let mut acc = _mm256_setzero_si256();
        for blk in 0..blocks {
            let av = _mm256_loadu_si256(a.as_ptr().add(blk * 4) as *const __m256i);
            let bv = _mm256_loadu_si256(b.as_ptr().add(blk * 4) as *const __m256i);
            acc = _mm256_add_epi64(acc, popcnt256(_mm256_and_si256(av, bv)));
        }
        let mut c = hsum(acc) as u32;
        for i in blocks * 4..words {
            c += (a[i] & b[i]).count_ones();
        }
        c
    }
}

/// AVX-512 tier: 512-bit AND + native `VPOPCNTQ`. Nightly-only (the
/// `avx512` cargo feature turns on `feature(stdarch_x86_avx512)` in
/// `lib.rs`); the stable CI toolchain never compiles this module, and
/// [`KernelCaps`] never reports the tier without it. Safety mirrors the
/// AVX2 module: feature-clamped dispatch, unaligned loads, block bounds
/// `blocks * 8 <= words`.
#[cfg(all(target_arch = "x86_64", feature = "avx512"))]
mod avx512 {
    use core::arch::x86_64::*;

    /// AVX-512 [`super::sweep4`]: 8-word blocks, one byte of the skip
    /// bitmap per block (bits `8b..8b+8` never straddle a bitmap word).
    #[target_feature(enable = "avx512f", enable = "avx512vpopcntdq")]
    pub unsafe fn sweep4(x: &[u64], wmsb: &[u64], skip: Option<&[u64]>) -> [u32; 4] {
        let words = x.len();
        let blocks = words / 8;
        let mut acc = [_mm512_setzero_si512(); 4];
        for b in 0..blocks {
            if let Some(s) = skip {
                let bit = b * 8;
                if (s[bit / 64] >> (bit % 64)) & 0xff == 0 {
                    continue;
                }
            }
            let xv = _mm512_loadu_si512(x.as_ptr().add(b * 8) as *const _);
            for (q, a) in acc.iter_mut().enumerate() {
                let wv = _mm512_loadu_si512(wmsb.as_ptr().add(q * words + b * 8) as *const _);
                *a = _mm512_add_epi64(*a, _mm512_popcnt_epi64(_mm512_and_si512(xv, wv)));
            }
        }
        let mut c = [0u32; 4];
        for (q, a) in acc.iter().enumerate() {
            c[q] = _mm512_reduce_add_epi64(*a) as u32;
        }
        for i in blocks * 8..words {
            let xv = x[i];
            for (q, cq) in c.iter_mut().enumerate() {
                *cq += (xv & wmsb[q * words + i]).count_ones();
            }
        }
        c
    }

    /// AVX-512 [`super::sweep4_pair`].
    #[target_feature(enable = "avx512f", enable = "avx512vpopcntdq")]
    pub unsafe fn sweep4_pair(
        x0: &[u64],
        x1: &[u64],
        wmsb: &[u64],
        skip: Option<&[u64]>,
    ) -> [[u32; 4]; 2] {
        let words = x0.len();
        let blocks = words / 8;
        let mut acc0 = [_mm512_setzero_si512(); 4];
        let mut acc1 = [_mm512_setzero_si512(); 4];
        for b in 0..blocks {
            if let Some(s) = skip {
                let bit = b * 8;
                if (s[bit / 64] >> (bit % 64)) & 0xff == 0 {
                    continue;
                }
            }
            let xv0 = _mm512_loadu_si512(x0.as_ptr().add(b * 8) as *const _);
            let xv1 = _mm512_loadu_si512(x1.as_ptr().add(b * 8) as *const _);
            for q in 0..4 {
                let wv = _mm512_loadu_si512(wmsb.as_ptr().add(q * words + b * 8) as *const _);
                acc0[q] =
                    _mm512_add_epi64(acc0[q], _mm512_popcnt_epi64(_mm512_and_si512(xv0, wv)));
                acc1[q] =
                    _mm512_add_epi64(acc1[q], _mm512_popcnt_epi64(_mm512_and_si512(xv1, wv)));
            }
        }
        let (mut a, mut b) = ([0u32; 4], [0u32; 4]);
        for q in 0..4 {
            a[q] = _mm512_reduce_add_epi64(acc0[q]) as u32;
            b[q] = _mm512_reduce_add_epi64(acc1[q]) as u32;
        }
        for i in blocks * 8..words {
            for q in 0..4 {
                let wv = wmsb[q * words + i];
                a[q] += (x0[i] & wv).count_ones();
                b[q] += (x1[i] & wv).count_ones();
            }
        }
        [a, b]
    }

    /// AVX-512 [`super::and_popcount`].
    #[target_feature(enable = "avx512f", enable = "avx512vpopcntdq")]
    pub unsafe fn and_popcount(a: &[u64], b: &[u64]) -> u32 {
        let words = a.len();
        let blocks = words / 8;
        let mut acc = _mm512_setzero_si512();
        for blk in 0..blocks {
            let av = _mm512_loadu_si512(a.as_ptr().add(blk * 8) as *const _);
            let bv = _mm512_loadu_si512(b.as_ptr().add(blk * 8) as *const _);
            acc = _mm512_add_epi64(acc, _mm512_popcnt_epi64(_mm512_and_si512(av, bv)));
        }
        let mut c = _mm512_reduce_add_epi64(acc) as u32;
        for i in blocks * 8..words {
            c += (a[i] & b[i]).count_ones();
        }
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::words_for;

    /// Every tier the build can actually select on this host (always
    /// includes Scalar; includes a vector tier when the hardware has
    /// it). Clamping makes requesting all three tiers safe anywhere.
    fn available_caps() -> Vec<KernelCaps> {
        let mut caps = vec![KernelCaps::select(Some(KernelTier::Scalar))];
        for t in [KernelTier::Avx2, KernelTier::Avx512] {
            let c = KernelCaps::select(Some(t));
            if caps.iter().all(|&p| p.tier() != c.tier()) {
                caps.push(c);
            }
        }
        caps
    }

    fn random_planes(rng: &mut Rng, words: usize, density: f64) -> Vec<u64> {
        (0..words)
            .map(|_| {
                if rng.next_f64() < density {
                    rng.next_u64()
                } else {
                    0
                }
            })
            .collect()
    }

    fn live_bitmap(wmsb: &[u64], words: usize) -> Vec<u64> {
        let mut skip = vec![0u64; words_for(words)];
        for i in 0..words {
            if (0..4).any(|q| wmsb[q * words + i] != 0) {
                skip[i / 64] |= 1 << (i % 64);
            }
        }
        skip
    }

    #[test]
    fn sweeps_bit_identical_across_tiers_and_skip() {
        let mut rng = Rng::new(61);
        for words in [0usize, 1, 2, 3, 4, 5, 7, 8, 9, 15, 16, 36, 65, 130] {
            for density in [0.0, 0.15, 0.6, 1.0] {
                let x0 = random_planes(&mut rng, words, 0.9);
                let x1 = random_planes(&mut rng, words, 0.9);
                let mut wmsb = Vec::with_capacity(4 * words);
                for _ in 0..4 {
                    wmsb.extend(random_planes(&mut rng, words, density));
                }
                let skip = live_bitmap(&wmsb, words);
                let want = sweep4_scalar(&x0, &wmsb);
                let want_pair = sweep4_pair_scalar(&x0, &x1, &wmsb);
                for caps in available_caps() {
                    let tier = caps.tier().name();
                    for sk in [None, Some(skip.as_slice())] {
                        assert_eq!(
                            sweep4(caps, &x0, &wmsb, sk),
                            want,
                            "tier {tier} words {words} density {density} skip {}",
                            sk.is_some()
                        );
                        assert_eq!(
                            sweep4_pair(caps, &x0, &x1, &wmsb, sk),
                            want_pair,
                            "pair tier {tier} words {words} density {density} skip {}",
                            sk.is_some()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn and_popcount_matches_frozen_reference() {
        let mut rng = Rng::new(62);
        for words in [0usize, 1, 3, 4, 6, 8, 17, 64, 129] {
            let a = random_planes(&mut rng, words, 0.7);
            let b = random_planes(&mut rng, words, 0.5);
            let want = crate::util::and_popcount(&a, &b);
            for caps in available_caps() {
                assert_eq!(
                    and_popcount(caps, &a, &b),
                    want,
                    "tier {} words {words}",
                    caps.tier().name()
                );
            }
        }
    }

    #[test]
    fn skip_is_exact_not_approximate() {
        // Zero out entire word-aligned stripes of the weight planes and
        // check the skipping sweep agrees with the dense sweep exactly.
        let mut rng = Rng::new(63);
        let words = 24;
        let x = random_planes(&mut rng, words, 1.0);
        let mut wmsb = Vec::new();
        for _ in 0..4 {
            wmsb.extend(random_planes(&mut rng, words, 1.0));
        }
        // Kill words 4..20 across all four planes: 4 live of 24.
        for q in 0..4 {
            for i in 4..20 {
                wmsb[q * words + i] = 0;
            }
        }
        let skip = live_bitmap(&wmsb, words);
        assert_eq!(skip[0].count_ones(), 8);
        for caps in available_caps() {
            assert_eq!(
                sweep4(caps, &x, &wmsb, Some(&skip)),
                sweep4_scalar(&x, &wmsb),
                "tier {}",
                caps.tier().name()
            );
        }
    }

    #[test]
    fn fold4_matches_shift_add() {
        let c = [3u32, 5, 7, 11];
        for p in 4..8 {
            let want = (3i64 << (p + 4)) + (5i64 << (p + 5)) + (7i64 << (p + 6))
                + (11i64 << (p + 7));
            assert_eq!(fold4(c, p), want);
        }
        assert_eq!(fold4([0; 4], 7), 0);
    }
}
