//! The PAC execution backend: every convolution/linear MAC runs through
//! the hybrid digital/sparsity computation of the PACiM bank (Eq. 4),
//! including the dynamic workload configuration of §5.
//!
//! This is the accuracy side of the reproduction: running a trained
//! quantized network through this backend instead of [`super::exec::ExactBackend`]
//! measures exactly the degradation the paper reports in Fig. 6 and
//! Table 2.
//!
//! Implementation notes (the "fast path" of DESIGN.md §9-L3):
//! - weight bit-planes are packed into u64 words once per layer
//!   (weight-stationary, like the PCU register file);
//! - activations are packed once per **layer** too: the blocked GEMM
//!   ([`MacBackend::gemm_layer`]) lowers the whole im2col matrix into a
//!   contiguous `[pixel][p][word]` slab (`tensor::PackedPatches`), then
//!   sweeps it in tiles of `TILE_PIXELS` pixels × all weight columns —
//!   each weight row is loaded exactly once per tile and each inner
//!   word-pass feeds two pixels' popcount lanes. The per-patch engine
//!   this replaced re-ran `BitPlanes::from_u8` per output pixel and
//!   allocated a fresh accumulator `Vec` per patch; it survives verbatim
//!   as [`PacBackend::gemm_per_patch_reference`], the baseline the bench
//!   harness and the property tests hold the blocked kernel against;
//! - a digital cycle is a word-AND + popcount — the software analogue of
//!   the 256-input adder tree. The word sweep is tiered
//!   (scalar/AVX2/AVX-512, [`super::simd`]) behind a clamped
//!   [`KernelCaps`], and the 4×4 kernels skip weight-plane zero words
//!   via per-column bitmaps built at prepare time (DESIGN.md §13) —
//!   both numerically inert: logits and modeled cycle statistics are
//!   bit-identical across tiers and with skipping on or off;
//! - the activation element sum for the zero-point correction is
//!   reconstructed from the sparsity counts (`Σ_p 2^p·Sx[p]`), never from
//!   the discarded LSB bits — faithfully mirroring the architecture.

use super::exec::{exact_gemm_tiled, GemmInput, MacBackend, RunStats, TILE_PIXELS};
use super::simd;
use crate::arch::bank_logic::{classify, spec_normalized, ThresholdSet};
use crate::arch::pcu::pcu_estimate_variance;
use crate::fault::{self, FaultConfig};
use crate::pac::compute_map::DynamicLevel;
use crate::pac::mac::sparsity_domain_sum_fast;
use crate::pac::sparsity::BitPlanes;
use crate::pac::{zero_point_correct, ComputeMap, PcuRounding};
use crate::tensor::{PackedPatches, Tensor};
use crate::util::and_popcount;
use crate::util::fastdiv::FastDiv;
use crate::util::{KernelCaps, KernelTier, Parallelism};

/// Columns whose live MSB-word fraction exceeds this threshold run the
/// dense linear sweep: near-dense bitmaps skip almost nothing, and the
/// per-word (scalar) or per-block (vector) bitmap test plus the broken
/// streaming pattern then cost more than they save — Snippet-3-style
/// density auto-off, decided once per column at prepare time.
pub const SKIP_DENSITY_AUTO_OFF: f64 = 0.75;

/// Below this many plane words a column's sweep is too short for the
/// bitmap iteration to pay for itself; skipping stays off.
pub const SKIP_MIN_WORDS: usize = 4;

/// Confidence-monitor thresholds for the PAC→exact escalation of
/// DESIGN.md §15 (`PacConfig::escalation`). A sample escalates when its
/// top-two logit margin falls below
/// `min_margin + sigma · σ_logit`, where `σ_logit` is the terminal PAC
/// layer's estimator standard deviation ([`pcu_estimate_variance`] plus
/// any injected PCU-noise variance) converted to logit units. When the
/// terminal layer runs digitally (first-layer-exact / short-DP
/// fallback), `σ_logit` is 0 and the monitor degenerates to a pure
/// margin floor.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EscalationConfig {
    /// Absolute logit-margin floor (logit units; 0 disables the
    /// unconditional floor).
    pub min_margin: f32,
    /// Estimator standard deviations of slack demanded on top of the
    /// floor (the Counting-Cards-style variance gate; 0 disables it).
    pub sigma: f64,
}

impl Default for EscalationConfig {
    fn default() -> Self {
        Self { min_margin: 0.0, sigma: 2.0 }
    }
}

impl EscalationConfig {
    /// Thresholds must be finite and non-negative; rejected at
    /// `EngineBuilder::build` with a typed error.
    pub fn validate(&self) -> Result<(), String> {
        if !(self.min_margin.is_finite() && self.min_margin >= 0.0) {
            return Err(format!(
                "escalation min_margin must be finite and ≥ 0, got {}",
                self.min_margin
            ));
        }
        if !(self.sigma.is_finite() && self.sigma >= 0.0) {
            return Err(format!("escalation sigma must be finite and ≥ 0, got {}", self.sigma));
        }
        Ok(())
    }
}

/// Configuration of the PAC backend.
#[derive(Debug, Clone)]
pub struct PacConfig {
    /// Base compute map (paper default: operand-based 4×4).
    pub map: ComputeMap,
    /// Dynamic workload thresholds; `None` disables speculation.
    pub thresholds: Option<ThresholdSet>,
    pub rounding: PcuRounding,
    /// Run the first compute layer exactly (§6.1: the initial CONV uses
    /// standard D-CiM for accurate feature extraction).
    pub first_layer_exact: bool,
    /// Layers whose DP length is below this run exactly. Default 512 =
    /// the paper's PAC operating range (Table 1 note d quotes RMSE for
    /// DP 512–4096) — *every* CONV/LINEAR layer of its benchmarks
    /// qualifies (3·3·64 = 576 … 4096). Our substitute model has shorter
    /// early layers, a substitution artifact; they stay digital,
    /// mirroring `python/compile/model.py::quantized_forward(min_dp=512)`.
    /// The out-of-range ablation (min_dp_len ∈ {0,150,256,300}) is
    /// reported in EXPERIMENTS.md §Table2 and confirms the paper's DP
    /// constraint from the negative side (accuracy collapses exactly
    /// where Fig. 3(c) predicts the RMSE exceeds competitors').
    pub min_dp_len: usize,
    /// The backend's own tile fan-out policy, used whenever the driver
    /// runs scalar (`run_model`); an enabled driver policy takes
    /// precedence (`Parallelism::or`). Bit-deterministic either way —
    /// tiles are independent and collected in order — so this only
    /// changes speed, never results.
    pub par: Parallelism,
    /// Let producers hand this backend's PAC layers their activations in
    /// sparsity-encoded form (MSB bit-planes + counters packed straight
    /// into the consumer's scratch slab) wherever the program allows it
    /// (conv→conv adjacency) — the §3.1/§4.5 inter-layer dataplane.
    /// Numerically inert: logits and cycle statistics are bit-identical
    /// either way; only the measured traffic ledger (and speed) change.
    /// Disable to force the dense-u8 round-trip on every edge.
    pub fuse_dataplane: bool,
    /// Popcount kernel tier for the digital sweeps: `None` (default)
    /// auto-detects the best supported tier, honoring the
    /// `PACIM_FORCE_KERNEL` env override; `Some(t)` requests tier `t`,
    /// clamped to what the host CPU supports (`util::kernel`).
    /// Numerically inert — every tier computes identical integers, so
    /// logits, cycle statistics, and traffic are bit-identical across
    /// tiers; only host speed changes.
    pub kernel: Option<KernelTier>,
    /// Skip weight-plane zero words in the digital sweeps: at prepare
    /// time each output column gets a bitmap of words that are nonzero
    /// in at least one MSB weight plane, and the sweeps visit only
    /// those (`x & 0 = 0` contributes nothing, so the skip is exact).
    /// Columns denser than [`SKIP_DENSITY_AUTO_OFF`] (or shorter than
    /// [`SKIP_MIN_WORDS`] words) auto-disable it. Numerically inert,
    /// like `kernel` — *modeled* cycle statistics are unchanged (the
    /// simulated bank still runs every digital cycle; skipping is a
    /// host-side shortcut past provably-zero popcounts).
    pub weight_skip: bool,
    /// Seeded CiM error model (`pacim::fault`, DESIGN.md §15). Default
    /// [`FaultConfig::off`]: no RNG is ever constructed and runs are
    /// bit-identical to a config without the field.
    pub fault: FaultConfig,
    /// Arm the confidence-gated PAC→exact escalation monitor: when set,
    /// every run accumulates the terminal PAC layer's estimator variance
    /// into `RunStats::estimator_var`, and `engine::Session` re-runs
    /// low-margin samples through the exact backend under the `auto`
    /// fidelity class. `None` (default) keeps the monitor compiled out
    /// of the epilogues.
    pub escalation: Option<EscalationConfig>,
}

impl Default for PacConfig {
    fn default() -> Self {
        Self {
            map: ComputeMap::operand_based(4, 4),
            thresholds: None,
            rounding: PcuRounding::RoundNearest,
            first_layer_exact: true,
            min_dp_len: 512,
            par: Parallelism::auto(),
            fuse_dataplane: true,
            kernel: None,
            weight_skip: true,
            fault: FaultConfig::off(),
            escalation: None,
        }
    }
}

impl PacConfig {
    /// Serving preset: identical numerics to the default config, but the
    /// per-tile fan-out is disabled — the serving executor
    /// (`runtime::PacExecutor`) parallelizes across batch *lanes*
    /// instead, and nesting both fan-outs wastes fork/join overhead on
    /// the small per-request layers.
    pub fn serving() -> Self {
        Self {
            par: Parallelism::off(),
            ..Self::default()
        }
    }
}

/// Pre-packed per-layer weight state.
struct PreparedLayer {
    /// Weight bit-planes in one contiguous block, laid out
    /// `[oc][q][word]` (§Perf: per-oc `Vec<Vec<u64>>` scattered the hot
    /// loop's reads across the heap; contiguous layout streams).
    planes: Vec<u64>,
    /// u64 words per plane.
    words: usize,
    /// `sw[oc]` = weight sparsity counts.
    sw: Vec<[u32; 8]>,
    /// Raw weight element sums (zero-point correction).
    w_sums: Vec<i64>,
    zpw: i32,
    k: usize,
    /// Reciprocal divider for the PCU divide-by-DP-length (§Perf).
    div: FastDiv,
    /// Exact fallback weights when this layer runs digitally.
    exact: Option<(Tensor<u8>, i32)>,
    /// Per-column live-word bitmaps over the MSB weight planes, laid
    /// out `[oc][skip_words]`: bit `i` of column `oc`'s bitmap is set
    /// iff plane word `i` is nonzero in ≥ 1 of the column's four MSB
    /// planes (`q ∈ 4..8`). Consulted by the skipping sweeps
    /// (`nn::simd`); see DESIGN.md §13.3 for the worked layout.
    skip: Vec<u64>,
    /// Bitmap words per column: `words.div_ceil(64)`.
    skip_words: usize,
    /// Per-column skip decision, resolved once at prepare time:
    /// `weight_skip` config AND `words >= SKIP_MIN_WORDS` AND live
    /// fraction ≤ [`SKIP_DENSITY_AUTO_OFF`].
    skip_on: Vec<bool>,
    /// Per-column live MSB-word counts (the density numerator; kept
    /// for the bench profile and the auto-off decision).
    live_words: Vec<u32>,
    /// Bit-cell flips injected into the MSB weight planes at prepare
    /// ("array programming") time — 0 when the fault channel is off.
    /// Recorded into the run's fault ledger once per `gemm_layer` call.
    weight_bits_flipped: u64,
}

impl PreparedLayer {
    /// The skip bitmap for column `oc`, or `None` when the density
    /// auto-off (or the config) disabled skipping for it.
    #[inline]
    fn skip_for(&self, oc: usize) -> Option<&[u64]> {
        if self.skip_on[oc] {
            Some(&self.skip[oc * self.skip_words..(oc + 1) * self.skip_words])
        } else {
            None
        }
    }
}

/// PAC backend implementing [`MacBackend`].
pub struct PacBackend {
    pub config: PacConfig,
    /// Kernel tier resolved once at construction: the config request
    /// clamped to the host (see `util::kernel`). Threaded into every
    /// tile kernel.
    caps: KernelCaps,
    layers: Vec<PreparedLayer>,
    /// Pre-expanded digital (p,q) sets per dynamic level, and the base map.
    level_maps: [ComputeMap; 4],
    /// `digital_set()` of each level map, expanded once so the per-pixel
    /// classification inside the tile loop allocates nothing.
    level_sets: [Vec<(usize, usize)>; 4],
}

impl PacBackend {
    pub fn new(config: PacConfig) -> Self {
        let level_maps = [
            DynamicLevel::Cycles10.map(),
            DynamicLevel::Cycles12.map(),
            DynamicLevel::Cycles14.map(),
            DynamicLevel::Cycles16.map(),
        ];
        let level_sets = [
            level_maps[0].digital_set(),
            level_maps[1].digital_set(),
            level_maps[2].digital_set(),
            level_maps[3].digital_set(),
        ];
        Self {
            caps: KernelCaps::select(config.kernel),
            config,
            layers: Vec::new(),
            level_maps,
            level_sets,
        }
    }

    /// The kernel capabilities this backend resolved at construction
    /// (config request → env override → CPUID probe, clamped to the
    /// host; see `util::kernel`).
    pub fn kernel_caps(&self) -> KernelCaps {
        self.caps
    }

    /// Weight-sparsity profile of a prepared layer, for bench
    /// reporting: `(live_msb_words, total_msb_words, skip_columns)` —
    /// live words counted per column over the union of the four MSB
    /// weight planes (exactly the bitmap the skipping sweeps consult),
    /// and the number of columns whose sweep actually skips.
    pub fn weight_skip_profile(&self, layer_id: usize) -> (u64, u64, usize) {
        let layer = &self.layers[layer_id];
        let live: u64 = layer.live_words.iter().map(|&v| v as u64).sum();
        let total = (layer.sw.len() * layer.words) as u64;
        let active = layer.skip_on.iter().filter(|&&b| b).count();
        (live, total, active)
    }

    fn level_index(level: DynamicLevel) -> usize {
        match level {
            DynamicLevel::Cycles10 => 0,
            DynamicLevel::Cycles12 => 1,
            DynamicLevel::Cycles14 => 2,
            DynamicLevel::Cycles16 => 3,
        }
    }

    fn level_map(&self, level: DynamicLevel) -> &ComputeMap {
        &self.level_maps[Self::level_index(level)]
    }

    /// The pre-blocked per-patch engine, kept as the frozen baseline:
    /// one `BitPlanes::from_u8` + one accumulator `Vec` per patch,
    /// columns fanned out per `config.par`, and the word sweep pinned
    /// to the **scalar** tier with no weight-skipping (via the single
    /// shared [`simd::sweep4_scalar`] helper). `benches/perf_hotpath`
    /// benchmarks the blocked GEMM against this and CI gates the ratio;
    /// `tests/proptests.rs` asserts end-to-end bit-identity between the
    /// two engines and across kernel tiers.
    pub fn gemm_per_patch_reference(
        &self,
        layer_id: usize,
        patch: &[u8],
        zpx: i32,
        stats: &mut RunStats,
    ) -> Vec<i64> {
        let layer = &self.layers[layer_id];
        let k = layer.k;
        debug_assert_eq!(patch.len(), k);
        let n = layer.sw.len();

        // First layer: standard D-CiM (exact).
        if let Some((w, zpw)) = &layer.exact {
            let wd = w.data();
            let row_acc = |oc: usize| -> i64 {
                let row = &wd[oc * k..(oc + 1) * k];
                let mut acc = 0i64;
                for (&x, &wv) in patch.iter().zip(row) {
                    acc += (x as i64 - zpx as i64) * (wv as i64 - *zpw as i64);
                }
                acc
            };
            let out = self.config.par.map_collect(n, row_acc);
            stats.macs += (n * k) as u64;
            stats.digital_cycles += (n as u64) * 64;
            return out;
        }

        let xp = BitPlanes::from_u8(patch);

        // Bank logic: choose the map for this output group (§5).
        let map = match &self.config.thresholds {
            Some(th) => {
                let spec = spec_normalized(&xp.pop, k as u32);
                let level = classify(spec, th);
                stats.levels.record(level);
                self.level_map(level)
            }
            None => &self.config.map,
        };
        let digital_set = map.digital_set();
        let dc = digital_set.len() as u64;

        // The raw element sum, reconstructed from sparsity (LSBs never
        // transmitted).
        let sum_x = xp.element_sum() as i64;

        let words = layer.words;
        let is_static_4x4 = digital_set.len() == 16
            && digital_set.iter().all(|&(p, q)| p >= 4 && q >= 4);
        let column = |oc: usize| -> i64 {
            let ocbase = oc * 8 * words;
            let mut raw = 0i64;
            if is_static_4x4 {
                // The single shared scalar word sweep (`nn::simd`) —
                // the reference is pinned to the scalar tier, no
                // skipping, so it stays the frozen bit-identity
                // baseline for every vector/skipping variant.
                let wmsb = &layer.planes[ocbase + 4 * words..ocbase + 8 * words];
                for p in 4..8 {
                    raw += simd::fold4(simd::sweep4_scalar(&xp.planes[p], wmsb), p);
                }
            } else {
                for &(p, q) in &digital_set {
                    let woff = ocbase + q * words;
                    let dp =
                        and_popcount(&xp.planes[p], &layer.planes[woff..woff + words]) as i64;
                    raw += dp << (p + q);
                }
            }
            raw += sparsity_domain_sum_fast(
                &xp.pop,
                &layer.sw[oc],
                &layer.div,
                map,
                self.config.rounding,
            );
            zero_point_correct(raw, sum_x, layer.w_sums[oc], k as i64, zpx, layer.zpw)
        };
        let out = self.config.par.map_collect(n, column);
        stats.macs += (n * k) as u64;
        stats.digital_cycles += dc * n as u64;
        stats.pcu_ops += (64 - dc) * n as u64;
        out
    }

    /// Dynamic-threshold tile body: classify **per pixel inside the tile
    /// loop** (§5 speculation), then run that pixel's digital set and
    /// epilogue. The 16-cycle level *is* the static 4×4 block, so those
    /// pixels take the fused kernel.
    #[allow(clippy::too_many_arguments)]
    fn tile_dynamic(
        &self,
        layer: &PreparedLayer,
        x: &PackedPatches,
        th: &ThresholdSet,
        p0: usize,
        pt: usize,
        zpx: i32,
        chunk: &mut [i64],
        ctx: &EpilogueCtx<'_>,
        local: &mut RunStats,
    ) {
        let n = layer.sw.len();
        let k = layer.k;
        let words = layer.words;
        let pstride = 8 * words;
        let xplanes = x.planes();
        for j in 0..pt {
            let pix = p0 + j;
            let pop = x.pop(pix);
            let spec = spec_normalized(pop, k as u32);
            let level = classify(spec, th);
            local.levels.record(level);
            let idx = Self::level_index(level);
            let map = &self.level_maps[idx];
            let set = &self.level_sets[idx];
            let row = &mut chunk[j * n..(j + 1) * n];
            if words > 0 {
                let xp = &xplanes[pix * pstride..(pix + 1) * pstride];
                if level == DynamicLevel::Cycles16 {
                    // The 16-cycle level *is* the static 4×4 block:
                    // tier-dispatched sweep, weight-skipping valid
                    // (only MSB planes are read).
                    for (oc, slot) in row.iter_mut().enumerate() {
                        let wp = &layer.planes[oc * pstride..(oc + 1) * pstride];
                        *slot =
                            pixel_digital_4x4(self.caps, xp, wp, words, layer.skip_for(oc));
                    }
                } else {
                    for (oc, slot) in row.iter_mut().enumerate() {
                        let wp = &layer.planes[oc * pstride..(oc + 1) * pstride];
                        let mut raw = 0i64;
                        for &(p, q) in set {
                            let dp = simd::and_popcount(
                                self.caps,
                                &xp[p * words..(p + 1) * words],
                                &wp[q * words..(q + 1) * words],
                            );
                            raw += (dp as i64) << (p + q);
                        }
                        *slot = raw;
                    }
                }
            }
            let sum_x = x.element_sum(pix);
            for (oc, slot) in row.iter_mut().enumerate() {
                let mut raw = *slot
                    + sparsity_domain_sum_fast(
                        pop,
                        &layer.sw[oc],
                        &layer.div,
                        map,
                        self.config.rounding,
                    );
                raw += ctx.perturb_and_monitor(layer, pop, pix, oc, map, local);
                *slot = zero_point_correct(raw, sum_x, layer.w_sums[oc], k as i64, zpx, layer.zpw);
            }
            let dc = set.len() as u64;
            local.digital_cycles += dc * n as u64;
            local.pcu_ops += (64 - dc) * n as u64;
        }
    }
}

/// Per-gemm runtime fault/monitor context threaded into the tile
/// epilogues. On the fault-free, monitor-off fast path both branches
/// are `None`/`false` and [`Self::perturb_and_monitor`] is a no-op the
/// optimizer can drop.
struct EpilogueCtx<'a> {
    layer_id: usize,
    /// Per-image content nonce (0 when faults are off).
    nonce: u64,
    /// PCU sampling-noise channel, when armed (`pcu_noise > 0`).
    noise: Option<&'a FaultConfig>,
    /// Accumulate this layer's estimator variance (terminal PAC layer
    /// of an escalation-armed config only).
    monitor: bool,
}

impl EpilogueCtx<'_> {
    const OFF: EpilogueCtx<'static> =
        EpilogueCtx { layer_id: 0, nonce: 0, noise: None, monitor: false };

    /// The additive PCU-noise delta for output `(pix, oc)` (0 when the
    /// channel is off), with the injection event and — when the monitor
    /// is armed — the output's estimator variance recorded into `local`.
    /// Draws are keyed by (seed, layer, image nonce, pixel, column):
    /// identical for every tile/lane schedule.
    #[inline]
    fn perturb_and_monitor(
        &self,
        layer: &PreparedLayer,
        pop: &[u32; 8],
        pix: usize,
        oc: usize,
        map: &ComputeMap,
        local: &mut RunStats,
    ) -> i64 {
        let mut delta = 0i64;
        let mut noise_var = 0.0f64;
        if let Some(fc) = self.noise {
            let sigma = fc.pcu_noise * layer.k as f64;
            let a = self.nonce ^ ((self.layer_id as u64) << 40) ^ pix as u64;
            let mut rng = fault::keyed_rng(fc.seed, fault::DOMAIN_PCU, a, oc as u64);
            delta = rng.gaussian(0.0, sigma).round() as i64;
            noise_var = sigma * sigma;
            local.faults.record_pcu(self.layer_id, 1);
        }
        if self.monitor {
            local.estimator_var +=
                pcu_estimate_variance(pop, &layer.sw[oc], layer.k as u32, map) + noise_var;
        }
        delta
    }
}

/// Fused single-pixel static-4×4 digital kernel: the four weight MSB
/// planes reduced in one pass per activation MSB plane, through the
/// tier-dispatched sweep ([`simd::sweep4`]) with optional weight
/// zero-word skipping.
fn pixel_digital_4x4(
    caps: KernelCaps,
    xp: &[u64],
    wp: &[u64],
    words: usize,
    skip: Option<&[u64]>,
) -> i64 {
    let wmsb = &wp[4 * words..8 * words];
    let mut raw = 0i64;
    for p in 4..8 {
        raw += simd::fold4(simd::sweep4(caps, &xp[p * words..(p + 1) * words], wmsb, skip), p);
    }
    raw
}

/// Static-4×4 digital kernel over one tile: weight-column outer loop
/// (each weight row streams through the tile exactly once, the tile's
/// activation planes stay L1-hot), pixel-**pair** inner loop (each
/// weight-word load feeds two pixels' popcount lanes). The word sweep
/// itself is the tier-dispatched [`simd::sweep4_pair`], with the
/// column's zero-word bitmap threaded in when its density cleared the
/// auto-off rule at prepare time.
fn tile_digital_4x4(
    caps: KernelCaps,
    layer: &PreparedLayer,
    x: &PackedPatches,
    p0: usize,
    pt: usize,
    chunk: &mut [i64],
) {
    let n = layer.sw.len();
    let words = layer.words;
    if words == 0 {
        return;
    }
    let pstride = 8 * words;
    let xplanes = x.planes();
    for oc in 0..n {
        let wp = &layer.planes[oc * pstride..(oc + 1) * pstride];
        let wmsb = &wp[4 * words..8 * words];
        let skip = layer.skip_for(oc);
        let mut j = 0;
        while j + 2 <= pt {
            let xa = &xplanes[(p0 + j) * pstride..(p0 + j + 1) * pstride];
            let xb = &xplanes[(p0 + j + 1) * pstride..(p0 + j + 2) * pstride];
            let (mut ra, mut rb) = (0i64, 0i64);
            for p in 4..8 {
                let [ca, cb] = simd::sweep4_pair(
                    caps,
                    &xa[p * words..(p + 1) * words],
                    &xb[p * words..(p + 1) * words],
                    wmsb,
                    skip,
                );
                ra += simd::fold4(ca, p);
                rb += simd::fold4(cb, p);
            }
            chunk[j * n + oc] = ra;
            chunk[(j + 1) * n + oc] = rb;
            j += 2;
        }
        if j < pt {
            let xp = &xplanes[(p0 + j) * pstride..(p0 + j + 1) * pstride];
            chunk[j * n + oc] = pixel_digital_4x4(caps, xp, wp, words, skip);
        }
    }
}

/// Generic digital kernel over one tile for an arbitrary (static)
/// digital set — same weight-outer / pixel-inner geometry, no pairing.
/// Tier-dispatched per plane pair; no weight-skipping (the bitmap only
/// covers the MSB planes the 4×4 kernels read, and non-4×4 maps are
/// off the hot path).
fn tile_digital_generic(
    caps: KernelCaps,
    layer: &PreparedLayer,
    x: &PackedPatches,
    set: &[(usize, usize)],
    p0: usize,
    pt: usize,
    chunk: &mut [i64],
) {
    let n = layer.sw.len();
    let words = layer.words;
    if words == 0 {
        return;
    }
    let pstride = 8 * words;
    let xplanes = x.planes();
    for oc in 0..n {
        let wp = &layer.planes[oc * pstride..(oc + 1) * pstride];
        for j in 0..pt {
            let xp = &xplanes[(p0 + j) * pstride..(p0 + j + 1) * pstride];
            let mut raw = 0i64;
            for &(p, q) in set {
                let dp = simd::and_popcount(
                    caps,
                    &xp[p * words..(p + 1) * words],
                    &wp[q * words..(q + 1) * words],
                );
                raw += (dp as i64) << (p + q);
            }
            chunk[j * n + oc] = raw;
        }
    }
}

/// Static-map epilogue over one tile: add the PCU sparsity-domain sum
/// (perturbed by the PCU-noise channel when armed) and apply the
/// zero-point correction for every (pixel, column).
#[allow(clippy::too_many_arguments)]
fn tile_epilogue(
    layer: &PreparedLayer,
    x: &PackedPatches,
    map: &ComputeMap,
    rounding: PcuRounding,
    p0: usize,
    pt: usize,
    zpx: i32,
    chunk: &mut [i64],
    ctx: &EpilogueCtx<'_>,
    local: &mut RunStats,
) {
    let n = layer.sw.len();
    let k = layer.k as i64;
    for j in 0..pt {
        let pix = p0 + j;
        let pop = x.pop(pix);
        let sum_x = x.element_sum(pix);
        let row = &mut chunk[j * n..(j + 1) * n];
        for (oc, slot) in row.iter_mut().enumerate() {
            let raw = *slot
                + sparsity_domain_sum_fast(pop, &layer.sw[oc], &layer.div, map, rounding)
                + ctx.perturb_and_monitor(layer, pop, pix, oc, map, local);
            *slot = zero_point_correct(raw, sum_x, layer.w_sums[oc], k, zpx, layer.zpw);
        }
    }
}

impl MacBackend for PacBackend {
    /// Residual skip edges ride the same config switch as the inter-layer
    /// dataplane: fused, the interpreter stores skip slots as packed
    /// planes + counters and eliminates the tail conv's add-in edge.
    /// Numerically inert either way — the add arithmetic is folded into
    /// the producing conv's requantize step in both modes.
    fn fuse_residual(&self) -> bool {
        self.config.fuse_dataplane
    }

    /// PAC layers consume the encoded dataplane: the digital block reads
    /// only the map's required activation planes (4 MSBs on the paper
    /// default; the §5 dynamic ladder is derived from the 4×4 base, so 4
    /// planes cover every level), the PCU and zero-point epilogue read
    /// only the counters. Digital-fallback layers (first layer, short
    /// DP) need the dense matrix and stay un-fused.
    fn packed_input_bits(&self, layer_id: usize) -> Option<u32> {
        if !self.config.fuse_dataplane {
            return None;
        }
        let layer = self.layers.get(layer_id)?;
        if layer.exact.is_some() || layer.k == 0 || layer.sw.is_empty() {
            return None;
        }
        let bits = if self.config.thresholds.is_some() {
            4
        } else {
            self.config.map.required_activation_bits().len() as u32
        };
        Some(bits)
    }

    fn prepare(&mut self, layer_id: usize, weight: &Tensor<u8>, zpw: i32) {
        assert_eq!(layer_id, self.layers.len(), "layers must prepare in order");
        let n = weight.shape()[0];
        let k = weight.shape()[1];
        let words = crate::util::words_for(k);
        let wd = weight.data();
        let mut planes = vec![0u64; n * 8 * words];
        let mut sw = Vec::with_capacity(n);
        let mut w_sums = Vec::with_capacity(n);
        let skip_words = crate::util::words_for(words);
        let mut skip = vec![0u64; n * skip_words];
        let mut skip_on = Vec::with_capacity(n);
        let mut live_words = Vec::with_capacity(n);
        let is_exact = (self.config.first_layer_exact && layer_id == 0)
            || k < self.config.min_dp_len;
        // Bit-cell fault channel: flip MSB plane bits at array-
        // programming time, before the skip bitmaps are derived — the
        // skip maps must describe the faulty array, not the nominal one.
        // Digital-fallback layers never read the planes and stay clean.
        let inject = !is_exact && self.config.fault.weight_msb_ber > 0.0;
        let tail_bits = if words == 0 { 0 } else { (k - (words - 1) * 64) as u32 };
        let mut weight_bits_flipped = 0u64;
        for oc in 0..n {
            let row = &wd[oc * k..(oc + 1) * k];
            let bp = BitPlanes::from_u8(row);
            // Sparsity registers and zero-point sums keep their nominal
            // values: the PCU and the correction were programmed from
            // the intended weights, and the drift against the faulty
            // array is exactly the injected error.
            sw.push(bp.pop);
            w_sums.push(row.iter().map(|&v| v as i64).sum());
            for q in 0..8 {
                let off = (oc * 8 + q) * words;
                planes[off..off + words].copy_from_slice(&bp.planes[q]);
            }
            if inject {
                let fc = &self.config.fault;
                for q in 4..8usize {
                    for i in 0..words {
                        let valid = if i + 1 == words { tail_bits } else { 64 };
                        let mut rng = fault::keyed_rng(
                            fc.seed,
                            fault::DOMAIN_WEIGHT,
                            ((layer_id as u64) << 32) | oc as u64,
                            ((q as u64) << 32) | i as u64,
                        );
                        let mask = fault::flip_mask(&mut rng, fc.weight_msb_ber, valid);
                        planes[(oc * 8 + q) * words + i] ^= mask;
                        weight_bits_flipped += mask.count_ones() as u64;
                    }
                }
            }
            // Live-word bitmap over the MSB planes + the per-column
            // density auto-off decision (DESIGN.md §13.3).
            let mut live = 0u32;
            for i in 0..words {
                if (4..8).any(|q| planes[(oc * 8 + q) * words + i] != 0) {
                    skip[oc * skip_words + i / 64] |= 1 << (i % 64);
                    live += 1;
                }
            }
            live_words.push(live);
            let density = if words == 0 { 1.0 } else { live as f64 / words as f64 };
            skip_on.push(
                self.config.weight_skip
                    && words >= SKIP_MIN_WORDS
                    && density <= SKIP_DENSITY_AUTO_OFF,
            );
        }
        let exact = if is_exact { Some((weight.clone(), zpw)) } else { None };
        self.layers.push(PreparedLayer {
            planes,
            words,
            sw,
            w_sums,
            zpw,
            k,
            div: FastDiv::for_dp_len(k as u64),
            exact,
            skip,
            skip_words,
            skip_on,
            live_words,
            weight_bits_flipped,
        });
    }

    /// Surface the configured error model to the interpreter (edge
    /// channel + per-image nonce); `None` when every channel is off so
    /// the fault-free path never hashes images or consults the config.
    fn fault(&self) -> Option<&FaultConfig> {
        if self.config.fault.is_off() {
            None
        } else {
            Some(&self.config.fault)
        }
    }

    fn gemm_layer(
        &self,
        layer_id: usize,
        input: GemmInput<'_>,
        pixels: usize,
        zpx: i32,
        nonce: u64,
        par: &Parallelism,
        planes: &mut PackedPatches,
        out: &mut Vec<i64>,
        stats: &mut RunStats,
    ) {
        let layer = &self.layers[layer_id];
        let k = layer.k;
        let n = layer.sw.len();
        out.clear();
        out.resize(pixels * n, 0);
        if pixels == 0 || n == 0 {
            return;
        }
        let par = par.or(&self.config.par);

        // First layer / short-DP fallback: standard D-CiM — the same
        // tiled exact kernel the exact backend runs. Such layers never
        // advertise `packed_input_bits`, so their input is always dense.
        if let Some((w, zpw)) = &layer.exact {
            let cols = match input {
                GemmInput::Dense(c) => c,
                GemmInput::Packed(_) => {
                    panic!("digital-fallback layer {layer_id} cannot consume packed input")
                }
            };
            debug_assert_eq!(cols.len(), pixels * k);
            exact_gemm_tiled(w.data(), *zpw, cols, k, n, pixels, zpx, &par, out, stats);
            return;
        }

        // (1) Lowering: either the producer already packed this layer's
        // im2col matrix (sparsity-encoded dataplane — zero work here),
        // or transpose the dense matrix into contiguous [pixel][p][word]
        // planes + per-pixel sparsity counts, once — not once per pixel.
        let x: &PackedPatches = match input {
            GemmInput::Packed(p) => {
                debug_assert_eq!(p.pixels(), pixels);
                debug_assert_eq!(p.k(), k);
                p
            }
            GemmInput::Dense(cols) => {
                debug_assert_eq!(cols.len(), pixels * k);
                planes.pack(cols, k, pixels, &par);
                planes
            }
        };

        // (2) Static-map precomputation (the dynamic path classifies per
        // pixel inside the tile loop instead).
        let digital_set = self.config.map.digital_set();
        let is4x4 = digital_set.len() == 16
            && digital_set.iter().all(|&(p, q)| p >= 4 && q >= 4);

        // Runtime fault/monitor context for the tile epilogues: the
        // PCU-noise channel when armed, and the estimator-variance
        // monitor on the **terminal** PAC layer of an escalation-armed
        // config (the layer whose accumulators become logits — the
        // variance the Session's margin gate thresholds against).
        let ctx = if self.config.fault.pcu_noise > 0.0
            || (self.config.escalation.is_some() && layer_id + 1 == self.layers.len())
        {
            EpilogueCtx {
                layer_id,
                nonce,
                noise: (self.config.fault.pcu_noise > 0.0).then_some(&self.config.fault),
                monitor: self.config.escalation.is_some()
                    && layer_id + 1 == self.layers.len(),
            }
        } else {
            EpilogueCtx::OFF
        };

        // (3) Blocked sweep: tiles of TILE_PIXELS pixels × the full
        // weight-column block per pass, fanned out over rayon per tile.
        // Each tile owns a disjoint [pixel][oc] slab range and pure
        // integer arithmetic, so any schedule is bit-identical.
        let locals = par.map_chunks_mut(out, TILE_PIXELS * n, |t, chunk| {
            let p0 = t * TILE_PIXELS;
            let pt = chunk.len() / n;
            let mut local = RunStats::default();
            match &self.config.thresholds {
                None => {
                    if is4x4 {
                        tile_digital_4x4(self.caps, layer, x, p0, pt, chunk);
                    } else {
                        tile_digital_generic(self.caps, layer, x, &digital_set, p0, pt, chunk);
                    }
                    tile_epilogue(
                        layer,
                        x,
                        &self.config.map,
                        self.config.rounding,
                        p0,
                        pt,
                        zpx,
                        chunk,
                        &ctx,
                        &mut local,
                    );
                    let dc = digital_set.len() as u64;
                    local.digital_cycles += dc * (pt * n) as u64;
                    local.pcu_ops += (64 - dc) * (pt * n) as u64;
                }
                Some(th) => self.tile_dynamic(layer, x, th, p0, pt, zpx, chunk, &ctx, &mut local),
            }
            local
        });
        for l in &locals {
            stats.merge(l);
        }
        stats.macs += (pixels * n * k) as u64;
        // Array-programming flips are a property of the prepared layer,
        // recorded once per gemm call so per-image ledgers compare
        // across batch sizes and par settings.
        if layer.weight_bits_flipped > 0 {
            stats.faults.record_weight(layer_id, layer.weight_bits_flipped);
        }
    }
}

/// Build a PAC backend prepared for `model`.
pub fn pac_backend(model: &super::layers::Model, config: PacConfig) -> PacBackend {
    use super::layers::Op;
    let mut b = PacBackend::new(config);
    let mut id = 0;
    for op in &model.ops {
        match op {
            Op::Conv2d(c) => {
                b.prepare(id, &c.weight, c.wparams.zero_point);
                id += 1;
            }
            Op::Linear(l) => {
                b.prepare(id, &l.weight, l.wparams.zero_point);
                id += 1;
            }
            _ => {}
        }
    }
    b
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::exec::{exact_backend, run_model_with, ModelScratch};
    use crate::nn::layers::{synthetic, tiny_resnet, Model};
    use crate::util::rng::Rng;

    /// Scalar-driver reference run (the low-level entry the engine
    /// facade is property-tested against in `tests/engine_api.rs`).
    fn run_model<B: MacBackend + Sync>(
        model: &Model,
        backend: &B,
        img: &[u8],
    ) -> (Vec<f32>, RunStats) {
        run_model_with(model, backend, img, &Parallelism::off(), &mut ModelScratch::default())
            .unwrap()
    }

    fn setup(seed: u64) -> (Model, Vec<u8>) {
        let mut rng = Rng::new(seed);
        let store = synthetic::random_store(&mut rng, 8, 10);
        let model = tiny_resnet(&store, 16, 10).unwrap();
        let img: Vec<u8> = (0..3 * 16 * 16).map(|_| rng.below(256) as u8).collect();
        (model, img)
    }

    #[test]
    fn all_digital_pac_matches_exact_engine() {
        // With an all-digital map and no first-layer special-casing, the
        // PAC backend must agree with the exact backend bit-for-bit —
        // the bit-serial identity (Eq. 1) end-to-end through a network.
        let (model, img) = setup(300);
        let exact = exact_backend(&model);
        let cfg = PacConfig {
            map: ComputeMap::all_digital(),
            first_layer_exact: false,
            min_dp_len: 0,
            ..PacConfig::default()
        };
        let pac = pac_backend(&model, cfg);
        let (a, _) = run_model(&model, &exact, &img);
        let (b, _) = run_model(&model, &pac, &img);
        assert_eq!(a, b);
    }

    #[test]
    fn pac_4x4_stays_close_to_exact() {
        let (model, img) = setup(301);
        let exact = exact_backend(&model);
        let pac = pac_backend(&model, PacConfig::default());
        let (a, _) = run_model(&model, &exact, &img);
        let (b, _) = run_model(&model, &pac, &img);
        // Logits drift but stay correlated; with random (untrained)
        // weights we only assert boundedness.
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 0.5 * a.iter().fold(0f32, |m, &v| m.max(v.abs())) + 1.0,
                "exact={x} pac={y}");
        }
    }

    #[test]
    fn parallel_tiles_bit_identical_to_scalar() {
        // Same model, same image: tile fan-out at every threshold must
        // reproduce the scalar backend's logits exactly.
        let (model, img) = setup(310);
        let scalar = pac_backend(
            &model,
            PacConfig {
                par: Parallelism::off(),
                ..PacConfig::default()
            },
        );
        let (a, _) = run_model(&model, &scalar, &img);
        for min_items in [1usize, 4, 32] {
            let par = pac_backend(
                &model,
                PacConfig {
                    par: Parallelism {
                        enabled: true,
                        min_items,
                    },
                    ..PacConfig::default()
                },
            );
            let (b, _) = run_model(&model, &par, &img);
            assert_eq!(a, b, "min_items={min_items}");
        }
    }

    #[test]
    fn fused_dataplane_bit_identical_to_dense_roundtrip() {
        // The sparsity-encoded handoff (producer requantize→scatter→pack)
        // must reproduce the dense-u8 round-trip exactly: same logits,
        // same cycle/op counters, same dynamic-level histogram — only
        // the measured traffic ledger may differ (encoded vs dense).
        let (model, img) = setup(320);
        for thresholds in [None, Some(ThresholdSet::new(0.10, 0.20, 0.35))] {
            let cfg = |fuse| PacConfig {
                thresholds,
                first_layer_exact: true,
                min_dp_len: 0,
                par: Parallelism::off(),
                fuse_dataplane: fuse,
                ..PacConfig::default()
            };
            let (a, sa) = run_model(&model, &pac_backend(&model, cfg(false)), &img);
            let (b, sb) = run_model(&model, &pac_backend(&model, cfg(true)), &img);
            assert_eq!(a, b);
            assert_eq!(sa.macs, sb.macs);
            assert_eq!(sa.digital_cycles, sb.digital_cycles);
            assert_eq!(sa.pcu_ops, sb.pcu_ops);
            assert_eq!(sa.levels, sb.levels);
            // tiny_resnet's fused dataplane encodes every inter-layer
            // edge except the single add→GAP handoff: 9 conv/save
            // payload edges plus 3 eliminated add-in edges and 2 encoded
            // post-add edges = 14 of 15 ledger rows. The round-trip run
            // encodes nothing, over the same 15 (layer, kind) keys.
            assert_eq!(sa.traffic.encoded_layer_count(), 0);
            assert_eq!(sa.traffic.layers().len(), sb.traffic.layers().len());
            assert_eq!(sb.traffic.encoded_layer_count(), 14);
            assert_eq!(sa.traffic.total_baseline_bits(), sb.traffic.total_baseline_bits());
            assert!(sb.traffic.total_bits() < sa.traffic.total_bits());
        }
    }

    #[test]
    fn blocked_matches_per_patch_reference_kernel_level() {
        // Direct kernel-level identity: gemm_layer vs the frozen
        // per-patch reference on one prepared layer, across maps,
        // thresholds, roundings, and non-tile-multiple pixel counts.
        let mut rng = Rng::new(320);
        let (n_oc, k) = (13, 150);
        let wq: Vec<u8> = (0..n_oc * k).map(|_| rng.below(256) as u8).collect();
        let weight = Tensor::from_vec(&[n_oc, k], wq);
        let configs = [
            PacConfig {
                first_layer_exact: false,
                min_dp_len: 0,
                par: Parallelism::off(),
                ..PacConfig::default()
            },
            PacConfig {
                first_layer_exact: false,
                min_dp_len: 0,
                par: Parallelism::off(),
                rounding: PcuRounding::Floor,
                map: ComputeMap::operand_based(5, 3),
                ..PacConfig::default()
            },
            PacConfig {
                first_layer_exact: false,
                min_dp_len: 0,
                par: Parallelism::off(),
                thresholds: Some(ThresholdSet::new(0.10, 0.20, 0.35)),
                ..PacConfig::default()
            },
            PacConfig {
                first_layer_exact: true, // exact fallback path
                min_dp_len: 0,
                par: Parallelism::off(),
                ..PacConfig::default()
            },
        ];
        for (ci, cfg) in configs.into_iter().enumerate() {
            let mut b = PacBackend::new(cfg);
            b.prepare(0, &weight, 128);
            for pixels in [1usize, 31, 32, 33, 77] {
                let cols: Vec<u8> =
                    (0..pixels * k).map(|_| rng.below(256) as u8).collect();
                let mut ref_stats = RunStats::default();
                let mut reference = Vec::new();
                for pix in 0..pixels {
                    reference.extend_from_slice(&b.gemm_per_patch_reference(
                        0,
                        &cols[pix * k..(pix + 1) * k],
                        7,
                        &mut ref_stats,
                    ));
                }
                for par in [
                    Parallelism::off(),
                    Parallelism {
                        enabled: true,
                        min_items: 1,
                    },
                ] {
                    let mut stats = RunStats::default();
                    let mut planes = PackedPatches::default();
                    let mut out = Vec::new();
                    b.gemm_layer(
                        0,
                        GemmInput::Dense(&cols),
                        pixels,
                        7,
                        0,
                        &par,
                        &mut planes,
                        &mut out,
                        &mut stats,
                    );
                    assert_eq!(out, reference, "cfg {ci} pixels {pixels}");
                    assert_eq!(stats.macs, ref_stats.macs, "cfg {ci} pixels {pixels}");
                    assert_eq!(stats.digital_cycles, ref_stats.digital_cycles);
                    assert_eq!(stats.pcu_ops, ref_stats.pcu_ops);
                    assert_eq!(stats.levels, ref_stats.levels);
                }
            }
        }
    }

    /// Weight matrix whose MSB planes die in word-aligned stripes:
    /// each 64-element block of a row is either "low" (all values
    /// < 16, so all four MSB plane words are zero) or free-range —
    /// the shape that makes the zero-word bitmaps actually skip.
    fn msb_sparse_weight(rng: &mut Rng, n_oc: usize, k: usize, p_low: f64) -> Tensor<u8> {
        let mut wq = Vec::with_capacity(n_oc * k);
        for _ in 0..n_oc {
            for blk in 0..k.div_ceil(64) {
                let low = rng.bernoulli(p_low);
                for _ in blk * 64..(blk * 64 + 64).min(k) {
                    wq.push(if low { rng.below(16) as u8 } else { rng.below(256) as u8 });
                }
            }
        }
        Tensor::from_vec(&[n_oc, k], wq)
    }

    #[test]
    fn kernel_tiers_and_weight_skip_bit_identical() {
        // Every kernel tier the host can run × weight-skipping on/off
        // must reproduce the forced-scalar no-skip outputs and cycle
        // statistics exactly, on both the static and dynamic paths.
        let mut rng = Rng::new(330);
        let (n_oc, k) = (9usize, 600usize);
        let weight = msb_sparse_weight(&mut rng, n_oc, k, 0.7);
        let pixels = 37;
        let cols: Vec<u8> = (0..pixels * k).map(|_| rng.below(256) as u8).collect();
        for thresholds in [None, Some(ThresholdSet::new(0.10, 0.20, 0.35))] {
            let run = |kernel: Option<KernelTier>, weight_skip: bool| {
                let mut b = PacBackend::new(PacConfig {
                    thresholds,
                    first_layer_exact: false,
                    min_dp_len: 0,
                    par: Parallelism::off(),
                    kernel,
                    weight_skip,
                    ..PacConfig::default()
                });
                b.prepare(0, &weight, 128);
                if weight_skip {
                    let (live, total, active) = b.weight_skip_profile(0);
                    assert!(active > 0, "crafted layer must skip ({live}/{total} live)");
                    assert!(live < total);
                }
                let mut stats = RunStats::default();
                let mut planes = PackedPatches::default();
                let mut out = Vec::new();
                b.gemm_layer(
                    0,
                    GemmInput::Dense(&cols),
                    pixels,
                    7,
                    0,
                    &Parallelism::off(),
                    &mut planes,
                    &mut out,
                    &mut stats,
                );
                (out, stats)
            };
            let (base_out, base) = run(Some(KernelTier::Scalar), false);
            for kernel in [
                Some(KernelTier::Scalar),
                Some(KernelTier::Avx2),
                Some(KernelTier::Avx512),
                None,
            ] {
                for skip in [false, true] {
                    let (out, stats) = run(kernel, skip);
                    assert_eq!(out, base_out, "kernel {kernel:?} skip {skip}");
                    assert_eq!(stats.digital_cycles, base.digital_cycles);
                    assert_eq!(stats.pcu_ops, base.pcu_ops);
                    assert_eq!(stats.levels, base.levels);
                }
            }
        }
    }

    #[test]
    fn dense_or_short_weights_disable_skip_via_auto_off() {
        // Random-dense weights: every MSB-union word is live, so the
        // density rule must turn skipping off for every column.
        let mut rng = Rng::new(331);
        let (n_oc, k) = (5usize, 600usize);
        let wq: Vec<u8> = (0..n_oc * k).map(|_| rng.below(256) as u8).collect();
        let mut b = PacBackend::new(PacConfig {
            first_layer_exact: false,
            min_dp_len: 0,
            ..PacConfig::default()
        });
        b.prepare(0, &Tensor::from_vec(&[n_oc, k], wq), 128);
        let (live, total, active) = b.weight_skip_profile(0);
        assert_eq!(active, 0, "random-dense weights must auto-off ({live}/{total})");
        assert_eq!(live, total);
        // Short layers (words < SKIP_MIN_WORDS) never skip, however
        // sparse: k = 150 → 3 words.
        let sparse_short = msb_sparse_weight(&mut rng, 4, 150, 0.9);
        b.prepare(1, &sparse_short, 128);
        assert_eq!(b.weight_skip_profile(1).2, 0);
        // And the config master switch wins over sparsity.
        let mut off = PacBackend::new(PacConfig {
            first_layer_exact: false,
            min_dp_len: 0,
            weight_skip: false,
            ..PacConfig::default()
        });
        off.prepare(0, &msb_sparse_weight(&mut rng, 4, 600, 0.8), 128);
        assert_eq!(off.weight_skip_profile(0).2, 0);
    }

    #[test]
    fn kernel_caps_resolved_and_clamped_at_construction() {
        let b = PacBackend::new(PacConfig {
            kernel: Some(KernelTier::Scalar),
            ..PacConfig::default()
        });
        assert_eq!(b.kernel_caps().tier(), KernelTier::Scalar);
        assert!(b.kernel_caps().forced());
        let auto = PacBackend::new(PacConfig::default());
        assert!(auto.kernel_caps().tier() <= auto.kernel_caps().supported());
    }

    #[test]
    fn empty_layer_k0_is_all_zero_and_does_not_panic() {
        // k = 0 (empty DP): the guarded divider (`FastDiv::for_dp_len`)
        // and the packing path both tolerate it; accumulators are zero.
        let weight = Tensor::from_vec(&[2, 0], Vec::new());
        let mut b = PacBackend::new(PacConfig {
            first_layer_exact: false,
            min_dp_len: 0,
            par: Parallelism::off(),
            ..PacConfig::default()
        });
        b.prepare(0, &weight, 3);
        let mut stats = RunStats::default();
        let mut planes = PackedPatches::default();
        let mut out = Vec::new();
        b.gemm_layer(
            0,
            GemmInput::Dense(&[]),
            4,
            5,
            0,
            &Parallelism::off(),
            &mut planes,
            &mut out,
            &mut stats,
        );
        assert_eq!(out, vec![0i64; 8]);
        assert_eq!(stats.macs, 0);
    }

    #[test]
    fn cycle_stats_reflect_map() {
        let (model, img) = setup(302);
        let pac = pac_backend(
            &model,
            PacConfig {
                first_layer_exact: false,
                min_dp_len: 0,
                ..PacConfig::default()
            },
        );
        let (_, stats) = run_model(&model, &pac, &img);
        // Every MAC ran the 16/48 split: avg cycles per MAC-output is 16,
        // but stats count per-(patch,oc): digital_cycles/(macs/k)… assert
        // the ratio digital:pcu = 16:48 exactly.
        assert_eq!(stats.pcu_ops, stats.digital_cycles * 3);
    }

    #[test]
    fn dynamic_config_reduces_cycles() {
        let (model, img) = setup(303);
        let static_cfg = PacConfig {
            first_layer_exact: true,
            min_dp_len: 0,
            ..PacConfig::default()
        };
        let dynamic_cfg = PacConfig {
            thresholds: Some(ThresholdSet::new(0.10, 0.20, 0.35)),
            first_layer_exact: true,
            min_dp_len: 0,
            ..PacConfig::default()
        };
        let pac_s = pac_backend(&model, static_cfg);
        let pac_d = pac_backend(&model, dynamic_cfg);
        let (_, st_s) = run_model(&model, &pac_s, &img);
        let (_, st_d) = run_model(&model, &pac_d, &img);
        assert!(st_d.digital_cycles <= st_s.digital_cycles);
        assert!(st_d.levels.total() > 0);
        assert!(st_d.levels.average_cycles() <= 16.0);
    }

    #[test]
    fn first_layer_exact_by_default() {
        let (model, img) = setup(304);
        let pac = pac_backend(&model, PacConfig::default());
        let exact = exact_backend(&model);
        // Only the stem differs in backend; run both and compare stem
        // outputs indirectly: with map=all_digital for non-first layers
        // the results must match the exact engine entirely.
        let cfg_all_digital = PacConfig {
            map: ComputeMap::all_digital(),
            ..PacConfig::default()
        };
        let pac_ad = pac_backend(&model, cfg_all_digital);
        let (a, _) = run_model(&model, &exact, &img);
        let (b, _) = run_model(&model, &pac_ad, &img);
        assert_eq!(a, b);
        let _ = pac; // silence
    }

    #[test]
    fn five_bit_approximation_tighter_than_four() {
        // §6.1: 5-bit approximation reduces the loss — its logits must be
        // at least as close to exact as 4-bit's on average.
        let (model, img) = setup(305);
        let exact = exact_backend(&model);
        let (a, _) = run_model(&model, &exact, &img);
        let mut errs = Vec::new();
        for bits in [4u32, 5u32] {
            let cfg = PacConfig {
                map: ComputeMap::operand_based(bits, bits),
                min_dp_len: 0,
                ..PacConfig::default()
            };
            let pac = pac_backend(&model, cfg);
            let (b, _) = run_model(&model, &pac, &img);
            let err: f32 = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).sum();
            errs.push(err);
        }
        assert!(
            errs[1] <= errs[0] * 1.1,
            "5-bit err {} should be ≲ 4-bit err {}",
            errs[1],
            errs[0]
        );
    }
}
