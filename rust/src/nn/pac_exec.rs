//! The PAC execution backend: every convolution/linear MAC runs through
//! the hybrid digital/sparsity computation of the PACiM bank (Eq. 4),
//! including the dynamic workload configuration of §5.
//!
//! This is the accuracy side of the reproduction: running a trained
//! quantized network through this backend instead of [`super::exec::ExactBackend`]
//! measures exactly the degradation the paper reports in Fig. 6 and
//! Table 2.
//!
//! Implementation notes (the "fast path" of DESIGN.md §9-L3):
//! - weight bit-planes are packed into u64 words once per layer
//!   (weight-stationary, like the PCU register file);
//! - a digital cycle is a word-AND + popcount — the software analogue of
//!   the 256-input adder tree;
//! - the activation element sum for the zero-point correction is
//!   reconstructed from the sparsity counts (`Σ_p 2^p·Sx[p]`), never from
//!   the discarded LSB bits — faithfully mirroring the architecture.

use super::exec::{MacBackend, RunStats};
use crate::arch::bank_logic::{classify, spec_normalized, ThresholdSet};
use crate::pac::compute_map::DynamicLevel;
use crate::pac::mac::sparsity_domain_sum_fast;
use crate::pac::sparsity::BitPlanes;
use crate::pac::{zero_point_correct, ComputeMap, PcuRounding};
use crate::tensor::Tensor;
use crate::util::and_popcount;
use crate::util::fastdiv::FastDiv;
use crate::util::Parallelism;

/// Configuration of the PAC backend.
#[derive(Debug, Clone)]
pub struct PacConfig {
    /// Base compute map (paper default: operand-based 4×4).
    pub map: ComputeMap,
    /// Dynamic workload thresholds; `None` disables speculation.
    pub thresholds: Option<ThresholdSet>,
    pub rounding: PcuRounding,
    /// Run the first compute layer exactly (§6.1: the initial CONV uses
    /// standard D-CiM for accurate feature extraction).
    pub first_layer_exact: bool,
    /// Layers whose DP length is below this run exactly. Default 512 =
    /// the paper's PAC operating range (Table 1 note d quotes RMSE for
    /// DP 512–4096) — *every* CONV/LINEAR layer of its benchmarks
    /// qualifies (3·3·64 = 576 … 4096). Our substitute model has shorter
    /// early layers, a substitution artifact; they stay digital,
    /// mirroring `python/compile/model.py::quantized_forward(min_dp=512)`.
    /// The out-of-range ablation (min_dp_len ∈ {0,150,256,300}) is
    /// reported in EXPERIMENTS.md §Table2 and confirms the paper's DP
    /// constraint from the negative side (accuracy collapses exactly
    /// where Fig. 3(c) predicts the RMSE exceeds competitors').
    pub min_dp_len: usize,
    /// Fan the per-output-channel (DP column) loop of `gemm` out over
    /// rayon. Bit-deterministic — columns are independent and collected
    /// in order — so this only changes speed, never results.
    pub par: Parallelism,
}

impl Default for PacConfig {
    fn default() -> Self {
        Self {
            map: ComputeMap::operand_based(4, 4),
            thresholds: None,
            rounding: PcuRounding::RoundNearest,
            first_layer_exact: true,
            min_dp_len: 512,
            par: Parallelism::auto(),
        }
    }
}

impl PacConfig {
    /// Serving preset: identical numerics to the default config, but the
    /// per-column fan-out is disabled — the serving executor
    /// (`runtime::PacExecutor`) parallelizes across batch *lanes*
    /// instead, and nesting both fan-outs wastes fork/join overhead on
    /// the small per-request layers.
    pub fn serving() -> Self {
        Self {
            par: Parallelism::off(),
            ..Self::default()
        }
    }
}

/// Pre-packed per-layer weight state.
struct PreparedLayer {
    /// Weight bit-planes in one contiguous block, laid out
    /// `[oc][q][word]` (§Perf: per-oc `Vec<Vec<u64>>` scattered the hot
    /// loop's reads across the heap; contiguous layout streams).
    planes: Vec<u64>,
    /// u64 words per plane.
    words: usize,
    /// `sw[oc]` = weight sparsity counts.
    sw: Vec<[u32; 8]>,
    /// Raw weight element sums (zero-point correction).
    w_sums: Vec<i64>,
    zpw: i32,
    k: usize,
    /// Reciprocal divider for the PCU divide-by-DP-length (§Perf).
    div: FastDiv,
    /// Exact fallback weights when this layer runs digitally.
    exact: Option<(Tensor<u8>, i32)>,
}

/// PAC backend implementing [`MacBackend`].
pub struct PacBackend {
    pub config: PacConfig,
    layers: Vec<PreparedLayer>,
    /// Pre-expanded digital (p,q) sets per dynamic level, and the base map.
    level_maps: [ComputeMap; 4],
}

impl PacBackend {
    pub fn new(config: PacConfig) -> Self {
        let level_maps = [
            DynamicLevel::Cycles10.map(),
            DynamicLevel::Cycles12.map(),
            DynamicLevel::Cycles14.map(),
            DynamicLevel::Cycles16.map(),
        ];
        Self {
            config,
            layers: Vec::new(),
            level_maps,
        }
    }

    fn level_map(&self, level: DynamicLevel) -> &ComputeMap {
        match level {
            DynamicLevel::Cycles10 => &self.level_maps[0],
            DynamicLevel::Cycles12 => &self.level_maps[1],
            DynamicLevel::Cycles14 => &self.level_maps[2],
            DynamicLevel::Cycles16 => &self.level_maps[3],
        }
    }
}

impl MacBackend for PacBackend {
    fn prepare(&mut self, layer_id: usize, weight: &Tensor<u8>, zpw: i32) {
        assert_eq!(layer_id, self.layers.len(), "layers must prepare in order");
        let n = weight.shape()[0];
        let k = weight.shape()[1];
        let words = crate::util::words_for(k);
        let wd = weight.data();
        let mut planes = vec![0u64; n * 8 * words];
        let mut sw = Vec::with_capacity(n);
        let mut w_sums = Vec::with_capacity(n);
        for oc in 0..n {
            let row = &wd[oc * k..(oc + 1) * k];
            let bp = BitPlanes::from_u8(row);
            sw.push(bp.pop);
            w_sums.push(row.iter().map(|&v| v as i64).sum());
            for q in 0..8 {
                let off = (oc * 8 + q) * words;
                planes[off..off + words].copy_from_slice(&bp.planes[q]);
            }
        }
        let exact = if (self.config.first_layer_exact && layer_id == 0)
            || k < self.config.min_dp_len
        {
            Some((weight.clone(), zpw))
        } else {
            None
        };
        self.layers.push(PreparedLayer {
            planes,
            words,
            sw,
            w_sums,
            zpw,
            k,
            div: FastDiv::new(k as u64),
            exact,
        });
    }

    fn gemm(&self, layer_id: usize, patch: &[u8], zpx: i32, stats: &mut RunStats) -> Vec<i64> {
        let layer = &self.layers[layer_id];
        let k = layer.k;
        debug_assert_eq!(patch.len(), k);
        let n = layer.sw.len();

        // First layer: standard D-CiM (exact).
        if let Some((w, zpw)) = &layer.exact {
            let wd = w.data();
            let row_acc = |oc: usize| -> i64 {
                let row = &wd[oc * k..(oc + 1) * k];
                let mut acc = 0i64;
                for (&x, &wv) in patch.iter().zip(row) {
                    acc += (x as i64 - zpx as i64) * (wv as i64 - *zpw as i64);
                }
                acc
            };
            let out = self.config.par.map_collect(n, row_acc);
            stats.macs += (n * k) as u64;
            stats.digital_cycles += (n as u64) * 64;
            return out;
        }

        let xp = BitPlanes::from_u8(patch);

        // Bank logic: choose the map for this output group (§5).
        let map = match &self.config.thresholds {
            Some(th) => {
                let spec = spec_normalized(&xp.pop, k as u32);
                let level = classify(spec, th);
                stats.levels.record(level);
                self.level_map(level)
            }
            None => &self.config.map,
        };
        let digital_set = map.digital_set();
        let dc = digital_set.len() as u64;

        // The raw element sum, reconstructed from sparsity (LSBs never
        // transmitted).
        let sum_x = xp.element_sum() as i64;

        let words = layer.words;
        // §Perf: the static operand-based 4x4 map (the overwhelmingly
        // common case) gets a fused kernel: for each activation MSB plane
        // the four weight MSB planes are reduced in one pass over the
        // words, reloading the x word once instead of four times.
        let is_static_4x4 = digital_set.len() == 16
            && digital_set.iter().all(|&(p, q)| p >= 4 && q >= 4);
        // One DP column per output channel — independent work items,
        // work-stolen across the pool when the layer is wide enough
        // (deterministic: pure integer math, collected in column order).
        let column = |oc: usize| -> i64 {
            let ocbase = oc * 8 * words;
            let mut raw = 0i64;
            if is_static_4x4 {
                for p in 4..8 {
                    let xpl = &xp.planes[p];
                    let w4 = &layer.planes[ocbase + 4 * words..ocbase + 5 * words];
                    let w5 = &layer.planes[ocbase + 5 * words..ocbase + 6 * words];
                    let w6 = &layer.planes[ocbase + 6 * words..ocbase + 7 * words];
                    let w7 = &layer.planes[ocbase + 7 * words..ocbase + 8 * words];
                    let (mut c4, mut c5, mut c6, mut c7) = (0u32, 0u32, 0u32, 0u32);
                    for i in 0..words {
                        let xw = xpl[i];
                        c4 += (xw & w4[i]).count_ones();
                        c5 += (xw & w5[i]).count_ones();
                        c6 += (xw & w6[i]).count_ones();
                        c7 += (xw & w7[i]).count_ones();
                    }
                    raw += (c4 as i64) << (p + 4);
                    raw += (c5 as i64) << (p + 5);
                    raw += (c6 as i64) << (p + 6);
                    raw += (c7 as i64) << (p + 7);
                }
            } else {
                for &(p, q) in &digital_set {
                    let woff = ocbase + q * words;
                    let dp =
                        and_popcount(&xp.planes[p], &layer.planes[woff..woff + words]) as i64;
                    raw += dp << (p + q);
                }
            }
            raw += sparsity_domain_sum_fast(
                &xp.pop,
                &layer.sw[oc],
                &layer.div,
                map,
                self.config.rounding,
            );
            zero_point_correct(raw, sum_x, layer.w_sums[oc], k as i64, zpx, layer.zpw)
        };
        let out = self.config.par.map_collect(n, column);
        stats.macs += (n * k) as u64;
        stats.digital_cycles += dc * n as u64;
        stats.pcu_ops += (64 - dc) * n as u64;
        out
    }
}

/// Build a PAC backend prepared for `model`.
pub fn pac_backend(model: &super::layers::Model, config: PacConfig) -> PacBackend {
    use super::layers::Op;
    let mut b = PacBackend::new(config);
    let mut id = 0;
    for op in &model.ops {
        match op {
            Op::Conv2d(c) => {
                b.prepare(id, &c.weight, c.wparams.zero_point);
                id += 1;
            }
            Op::Linear(l) => {
                b.prepare(id, &l.weight, l.wparams.zero_point);
                id += 1;
            }
            _ => {}
        }
    }
    b
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::exec::{exact_backend, run_model};
    use crate::nn::layers::{synthetic, tiny_resnet};
    use crate::util::rng::Rng;

    fn setup(seed: u64) -> (crate::nn::layers::Model, Vec<u8>) {
        let mut rng = Rng::new(seed);
        let store = synthetic::random_store(&mut rng, 8, 10);
        let model = tiny_resnet(&store, 16, 10).unwrap();
        let img: Vec<u8> = (0..3 * 16 * 16).map(|_| rng.below(256) as u8).collect();
        (model, img)
    }

    #[test]
    fn all_digital_pac_matches_exact_engine() {
        // With an all-digital map and no first-layer special-casing, the
        // PAC backend must agree with the exact backend bit-for-bit —
        // the bit-serial identity (Eq. 1) end-to-end through a network.
        let (model, img) = setup(300);
        let exact = exact_backend(&model);
        let cfg = PacConfig {
            map: ComputeMap::all_digital(),
            thresholds: None,
            rounding: PcuRounding::RoundNearest,
            first_layer_exact: false,
            min_dp_len: 0,
            par: Parallelism::auto(),
        };
        let pac = pac_backend(&model, cfg);
        let (a, _) = run_model(&model, &exact, &img);
        let (b, _) = run_model(&model, &pac, &img);
        assert_eq!(a, b);
    }

    #[test]
    fn pac_4x4_stays_close_to_exact() {
        let (model, img) = setup(301);
        let exact = exact_backend(&model);
        let pac = pac_backend(&model, PacConfig::default());
        let (a, _) = run_model(&model, &exact, &img);
        let (b, _) = run_model(&model, &pac, &img);
        // Logits drift but stay correlated; with random (untrained)
        // weights we only assert boundedness.
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 0.5 * a.iter().fold(0f32, |m, &v| m.max(v.abs())) + 1.0,
                "exact={x} pac={y}");
        }
    }

    #[test]
    fn parallel_columns_bit_identical_to_scalar() {
        // Same model, same image: column fan-out at every threshold must
        // reproduce the scalar backend's logits exactly.
        let (model, img) = setup(310);
        let scalar = pac_backend(
            &model,
            PacConfig {
                par: Parallelism::off(),
                ..PacConfig::default()
            },
        );
        let (a, _) = run_model(&model, &scalar, &img);
        for min_items in [1usize, 4, 32] {
            let par = pac_backend(
                &model,
                PacConfig {
                    par: Parallelism {
                        enabled: true,
                        min_items,
                    },
                    ..PacConfig::default()
                },
            );
            let (b, _) = run_model(&model, &par, &img);
            assert_eq!(a, b, "min_items={min_items}");
        }
    }

    #[test]
    fn cycle_stats_reflect_map() {
        let (model, img) = setup(302);
        let pac = pac_backend(
            &model,
            PacConfig {
                first_layer_exact: false,
                min_dp_len: 0,
                ..PacConfig::default()
            },
        );
        let (_, stats) = run_model(&model, &pac, &img);
        // Every MAC ran the 16/48 split: avg cycles per MAC-output is 16,
        // but stats count per-(patch,oc): digital_cycles/(macs/k)… assert
        // the ratio digital:pcu = 16:48 exactly.
        assert_eq!(stats.pcu_ops, stats.digital_cycles * 3);
    }

    #[test]
    fn dynamic_config_reduces_cycles() {
        let (model, img) = setup(303);
        let static_cfg = PacConfig {
            first_layer_exact: true,
            min_dp_len: 0,
            ..PacConfig::default()
        };
        let dynamic_cfg = PacConfig {
            thresholds: Some(ThresholdSet::new(0.10, 0.20, 0.35)),
            first_layer_exact: true,
            min_dp_len: 0,
            ..PacConfig::default()
        };
        let pac_s = pac_backend(&model, static_cfg);
        let pac_d = pac_backend(&model, dynamic_cfg);
        let (_, st_s) = run_model(&model, &pac_s, &img);
        let (_, st_d) = run_model(&model, &pac_d, &img);
        assert!(st_d.digital_cycles <= st_s.digital_cycles);
        assert!(st_d.levels.total() > 0);
        assert!(st_d.levels.average_cycles() <= 16.0);
    }

    #[test]
    fn first_layer_exact_by_default() {
        let (model, img) = setup(304);
        let pac = pac_backend(&model, PacConfig::default());
        let exact = exact_backend(&model);
        // Only the stem differs in backend; run both and compare stem
        // outputs indirectly: with map=all_digital for non-first layers
        // the results must match the exact engine entirely.
        let cfg_all_digital = PacConfig {
            map: ComputeMap::all_digital(),
            ..PacConfig::default()
        };
        let pac_ad = pac_backend(&model, cfg_all_digital);
        let (a, _) = run_model(&model, &exact, &img);
        let (b, _) = run_model(&model, &pac_ad, &img);
        assert_eq!(a, b);
        let _ = pac; // silence
    }

    #[test]
    fn five_bit_approximation_tighter_than_four() {
        // §6.1: 5-bit approximation reduces the loss — its logits must be
        // at least as close to exact as 4-bit's on average.
        let (model, img) = setup(305);
        let exact = exact_backend(&model);
        let (a, _) = run_model(&model, &exact, &img);
        let mut errs = Vec::new();
        for bits in [4u32, 5u32] {
            let cfg = PacConfig {
                map: ComputeMap::operand_based(bits, bits),
                min_dp_len: 0,
                ..PacConfig::default()
            };
            let pac = pac_backend(&model, cfg);
            let (b, _) = run_model(&model, &pac, &img);
            let err: f32 = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).sum();
            errs.push(err);
        }
        assert!(
            errs[1] <= errs[0] * 1.1,
            "5-bit err {} should be ≲ 4-bit err {}",
            errs[1],
            errs[0]
        );
    }
}
