//! Quantized-model IR and the tiny-model builders.
//!
//! A model is a sequence of ops over per-image CHW `u8` activations with
//! an explicit skip-connection stack (sufficient for the ResNet/VGG
//! families). Topology is defined identically in
//! `python/compile/model.py`; weights and quantization parameters come
//! from `weights.bin`. The integration tests assert the rust engines and
//! the exported JAX model agree on real inputs.

use super::weights::WeightStore;
use crate::tensor::{Conv2dGeom, QuantParams, Tensor};
use crate::{Error, Result};

/// Convolution layer with folded BN and PTQ parameters.
#[derive(Debug, Clone)]
pub struct ConvLayer {
    pub name: String,
    pub geom: Conv2dGeom,
    /// `[out_c, dp_len]` quantized weights (OIHW flattened per row).
    pub weight: Tensor<u8>,
    pub wparams: QuantParams,
    /// Float bias (includes the BN shift), applied post-dequantization.
    pub bias: Vec<f32>,
    pub out_params: QuantParams,
    pub relu: bool,
}

/// Fully-connected layer.
#[derive(Debug, Clone)]
pub struct LinearLayer {
    pub name: String,
    pub in_f: usize,
    pub out_f: usize,
    /// `[out_f, in_f]` quantized weights.
    pub weight: Tensor<u8>,
    pub wparams: QuantParams,
    pub bias: Vec<f32>,
    /// `None` ⇒ this layer emits float logits (the classifier head).
    pub out_params: Option<QuantParams>,
    pub relu: bool,
}

/// One op of the sequential program.
#[derive(Debug, Clone)]
pub enum Op {
    Conv2d(ConvLayer),
    Linear(LinearLayer),
    /// 2×2/2 max pooling (quantization-transparent).
    MaxPool2,
    /// Global average pooling to 1×1 (rounds in the quantized domain).
    GlobalAvgPool,
    /// Push the current activation (and its params) onto the skip stack.
    SaveSkip,
    /// Pop the skip stack and add: `out = quant(deq(a) + deq(skip))`.
    AddSkip { out_params: QuantParams, relu: bool },
}

/// A quantized model.
#[derive(Debug, Clone)]
pub struct Model {
    pub name: String,
    pub ops: Vec<Op>,
    pub input_params: QuantParams,
    pub in_c: usize,
    pub in_hw: usize,
    pub num_classes: usize,
}

impl Model {
    /// Compute layers only (conv + linear), for mapping/energy analytics.
    pub fn compute_layers(&self) -> Vec<(&str, Conv2dGeom)> {
        self.ops
            .iter()
            .filter_map(|op| match op {
                Op::Conv2d(c) => Some((c.name.as_str(), c.geom)),
                Op::Linear(l) => Some((
                    l.name.as_str(),
                    Conv2dGeom {
                        in_c: l.in_f,
                        in_h: 1,
                        in_w: 1,
                        out_c: l.out_f,
                        kh: 1,
                        kw: 1,
                        stride: 1,
                        pad: 0,
                    },
                )),
                _ => None,
            })
            .collect()
    }

    /// Total MACs per image.
    pub fn macs(&self) -> u64 {
        self.compute_layers().iter().map(|(_, g)| g.macs()).sum()
    }
}

fn load_conv(
    store: &WeightStore,
    name: &str,
    geom: Conv2dGeom,
    relu: bool,
) -> Result<ConvLayer> {
    let w = store.get(&format!("{name}.w"))?;
    let expect = [geom.out_c, geom.dp_len()];
    if w.shape != expect {
        return Err(Error::Shape(format!(
            "{name}.w shape {:?} != expected {:?}",
            w.shape, expect
        )));
    }
    let bias = store.get(&format!("{name}.b"))?.as_f32()?;
    if bias.len() != geom.out_c {
        return Err(Error::Shape(format!("{name}.b length mismatch")));
    }
    Ok(ConvLayer {
        name: name.into(),
        geom,
        weight: Tensor::from_vec(&expect, w.as_u8()?.to_vec()),
        wparams: w.quant_params(),
        bias,
        out_params: store.get_qparams(&format!("{name}.oq"))?,
        relu,
    })
}

fn load_linear(
    store: &WeightStore,
    name: &str,
    in_f: usize,
    out_f: usize,
    logits: bool,
) -> Result<LinearLayer> {
    let w = store.get(&format!("{name}.w"))?;
    let expect = [out_f, in_f];
    if w.shape != expect {
        return Err(Error::Shape(format!(
            "{name}.w shape {:?} != expected {:?}",
            w.shape, expect
        )));
    }
    let bias = store.get(&format!("{name}.b"))?.as_f32()?;
    Ok(LinearLayer {
        name: name.into(),
        in_f,
        out_f,
        weight: Tensor::from_vec(&expect, w.as_u8()?.to_vec()),
        wparams: w.quant_params(),
        bias,
        out_params: if logits {
            None
        } else {
            Some(store.get_qparams(&format!("{name}.oq"))?)
        },
        relu: !logits,
    })
}

/// The `tiny_resnet` topology trained at build time (see
/// `python/compile/model.py::tiny_resnet`, which must stay in sync):
///
/// ```text
/// stem:   conv3×3(3→C)/1 + relu
/// block1: save; conv3×3(C→C)+relu; conv3×3(C→C); add+relu
/// down1:  conv3×3(C→2C)/2 + relu
/// block2: residual block @2C
/// down2:  conv3×3(2C→4C)/2 + relu
/// block3: residual block @4C
/// head:   global avgpool; linear(4C→classes) → logits
/// ```
pub fn tiny_resnet(store: &WeightStore, hw: usize, num_classes: usize) -> Result<Model> {
    // Infer width from the stem weights: [C, 27].
    let c = store.get("stem.w")?.shape[0];
    let conv = |n: &str, ic, oc, hw, s, relu| -> Result<Op> {
        Ok(Op::Conv2d(load_conv(
            store,
            n,
            Conv2dGeom {
                in_c: ic,
                in_h: hw,
                in_w: hw,
                out_c: oc,
                kh: 3,
                kw: 3,
                stride: s,
                pad: 1,
            },
            relu,
        )?))
    };
    let block = |tag: &str, ch, hw, ops: &mut Vec<Op>| -> Result<()> {
        ops.push(Op::SaveSkip);
        ops.push(conv(&format!("{tag}.conv1"), ch, ch, hw, 1, true)?);
        ops.push(conv(&format!("{tag}.conv2"), ch, ch, hw, 1, false)?);
        ops.push(Op::AddSkip {
            out_params: store.get_qparams(&format!("{tag}.add.oq"))?,
            relu: true,
        });
        Ok(())
    };
    let mut ops = Vec::new();
    ops.push(conv("stem", 3, c, hw, 1, true)?);
    block("block1", c, hw, &mut ops)?;
    ops.push(conv("down1", c, 2 * c, hw, 2, true)?);
    block("block2", 2 * c, hw / 2, &mut ops)?;
    ops.push(conv("down2", 2 * c, 4 * c, hw / 2, 2, true)?);
    block("block3", 4 * c, hw / 4, &mut ops)?;
    ops.push(Op::GlobalAvgPool);
    ops.push(Op::Linear(load_linear(store, "fc", 4 * c, num_classes, true)?));
    Ok(Model {
        name: format!("tiny_resnet_c{c}"),
        ops,
        input_params: store.get_qparams("input.oq")?,
        in_c: 3,
        in_hw: hw,
        num_classes,
    })
}

/// The `tiny_vgg` topology (second accuracy model, Table 2 substitution):
///
/// ```text
/// conv3×3(3→C)+relu; conv3×3(C→C)+relu; maxpool
/// conv3×3(C→2C)+relu; conv3×3(2C→2C)+relu; maxpool
/// conv3×3(2C→4C)+relu; conv3×3(4C→4C)+relu; maxpool
/// global avgpool; linear(4C→classes)
/// ```
pub fn tiny_vgg(store: &WeightStore, hw: usize, num_classes: usize) -> Result<Model> {
    let c = store.get("conv1a.w")?.shape[0];
    let conv = |n: &str, ic, oc, hw| -> Result<Op> {
        Ok(Op::Conv2d(load_conv(
            store,
            n,
            Conv2dGeom {
                in_c: ic,
                in_h: hw,
                in_w: hw,
                out_c: oc,
                kh: 3,
                kw: 3,
                stride: 1,
                pad: 1,
            },
            true,
        )?))
    };
    let ops = vec![
        conv("conv1a", 3, c, hw)?,
        conv("conv1b", c, c, hw)?,
        Op::MaxPool2,
        conv("conv2a", c, 2 * c, hw / 2)?,
        conv("conv2b", 2 * c, 2 * c, hw / 2)?,
        Op::MaxPool2,
        conv("conv3a", 2 * c, 4 * c, hw / 4)?,
        conv("conv3b", 4 * c, 4 * c, hw / 4)?,
        Op::MaxPool2,
        Op::GlobalAvgPool,
        Op::Linear(load_linear(store, "fc", 4 * c, num_classes, true)?),
    ];
    Ok(Model {
        name: format!("tiny_vgg_c{c}"),
        ops,
        input_params: store.get_qparams("input.oq")?,
        in_c: 3,
        in_hw: hw,
        num_classes,
    })
}

pub mod synthetic {
    //! Deterministic random-model construction — engine tests, benches,
    //! and the artifact-free serving path (`workload::synthetic`) all
    //! build a `tiny_resnet` from this store when `artifacts/` has not
    //! been compiled.
    use super::*;
    use crate::quant::{calibrate_minmax, calibrate_weights_symmetric};
    use crate::util::rng::Rng;

    /// Insert a 3×3 conv layer (`name.w`/`name.b`/`name.oq`) drawn from
    /// the `rng` stream.
    fn insert_conv(rng: &mut Rng, s: &mut WeightStore, name: &str, ic: usize, oc: usize) {
        let k = ic * 9;
        let wf: Vec<f32> = (0..oc * k)
            .map(|_| (rng.next_f32() - 0.5) * 0.6)
            .collect();
        let wt = Tensor::from_vec(&[oc, k], wf.clone());
        let wp = calibrate_weights_symmetric(&wt);
        let wq: Vec<u8> = wf.iter().map(|&v| wp.quantize(v)).collect();
        s.insert_u8(&format!("{name}.w"), &[oc, k], wq, wp);
        let b: Vec<f32> = (0..oc).map(|_| (rng.next_f32() - 0.5) * 0.1).collect();
        s.insert_f32(&format!("{name}.b"), &[oc], &b);
        let oqp = calibrate_minmax(0.0, 4.0);
        s.insert_f32(
            &format!("{name}.oq"),
            &[2],
            &[oqp.scale, oqp.zero_point as f32],
        );
    }

    /// Insert the classifier head (`fc.w`/`fc.b`) with `k` input features.
    fn insert_fc(rng: &mut Rng, s: &mut WeightStore, k: usize, classes: usize) {
        let wf: Vec<f32> = (0..classes * k)
            .map(|_| (rng.next_f32() - 0.5) * 0.8)
            .collect();
        let wt = Tensor::from_vec(&[classes, k], wf.clone());
        let wp = calibrate_weights_symmetric(&wt);
        let wq: Vec<u8> = wf.iter().map(|&v| wp.quantize(v)).collect();
        s.insert_u8("fc.w", &[classes, k], wq, wp);
        let b: Vec<f32> = (0..classes).map(|_| (rng.next_f32() - 0.5) * 0.1).collect();
        s.insert_f32("fc.b", &[classes], &b);
    }

    /// A fully-populated `tiny_resnet` weight store with width `c` and
    /// `classes` output classes, deterministic in the `rng` stream.
    pub fn random_store(rng: &mut Rng, c: usize, classes: usize) -> WeightStore {
        let mut s = WeightStore::default();
        s.insert_f32("input.oq", &[2], &[1.0 / 64.0, 128.0]);
        insert_conv(rng, &mut s, "stem", 3, c);
        for (tag, ch) in [("block1", c), ("block2", 2 * c), ("block3", 4 * c)] {
            insert_conv(rng, &mut s, &format!("{tag}.conv1"), ch, ch);
            insert_conv(rng, &mut s, &format!("{tag}.conv2"), ch, ch);
            let oqp = calibrate_minmax(0.0, 6.0);
            s.insert_f32(
                &format!("{tag}.add.oq"),
                &[2],
                &[oqp.scale, oqp.zero_point as f32],
            );
        }
        insert_conv(rng, &mut s, "down1", c, 2 * c);
        insert_conv(rng, &mut s, "down2", 2 * c, 4 * c);
        insert_fc(rng, &mut s, 4 * c, classes);
        s
    }

    /// A fully-populated `tiny_vgg` weight store with base width `c` and
    /// `classes` output classes, deterministic in the `rng` stream —
    /// the second-tenant model of the multi-model serving path.
    pub fn random_vgg_store(rng: &mut Rng, c: usize, classes: usize) -> WeightStore {
        let mut s = WeightStore::default();
        s.insert_f32("input.oq", &[2], &[1.0 / 64.0, 128.0]);
        insert_conv(rng, &mut s, "conv1a", 3, c);
        insert_conv(rng, &mut s, "conv1b", c, c);
        insert_conv(rng, &mut s, "conv2a", c, 2 * c);
        insert_conv(rng, &mut s, "conv2b", 2 * c, 2 * c);
        insert_conv(rng, &mut s, "conv3a", 2 * c, 4 * c);
        insert_conv(rng, &mut s, "conv3b", 4 * c, 4 * c);
        insert_fc(rng, &mut s, 4 * c, classes);
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn tiny_resnet_builds_from_store() {
        let mut rng = Rng::new(123);
        let store = synthetic::random_store(&mut rng, 8, 10);
        let m = tiny_resnet(&store, 16, 10).unwrap();
        assert_eq!(m.num_classes, 10);
        assert_eq!(m.in_hw, 16);
        // stem + 3 blocks (2 convs each) + 2 downsamples = 9 convs.
        let convs = m
            .ops
            .iter()
            .filter(|o| matches!(o, Op::Conv2d(_)))
            .count();
        assert_eq!(convs, 9);
        assert!(m.macs() > 0);
    }

    #[test]
    fn tiny_vgg_builds_from_store() {
        let mut rng = Rng::new(321);
        let store = synthetic::random_vgg_store(&mut rng, 8, 10);
        let m = tiny_vgg(&store, 16, 10).unwrap();
        assert_eq!(m.num_classes, 10);
        assert_eq!(m.in_hw, 16);
        let convs = m
            .ops
            .iter()
            .filter(|o| matches!(o, Op::Conv2d(_)))
            .count();
        assert_eq!(convs, 6);
        assert!(m.macs() > 0);
    }

    #[test]
    fn missing_weight_is_reported() {
        let store = WeightStore::default();
        let err = tiny_resnet(&store, 16, 10).unwrap_err();
        assert!(err.to_string().contains("stem.w"), "{err}");
    }

    #[test]
    fn shape_mismatch_detected() {
        let mut rng = Rng::new(124);
        let mut store = synthetic::random_store(&mut rng, 8, 10);
        // Corrupt: replace stem weights with the wrong K.
        let e = store.entries.get_mut("stem.w").unwrap();
        e.shape = vec![8, 10];
        e.data.truncate(80);
        let err = tiny_resnet(&store, 16, 10).unwrap_err();
        assert!(err.to_string().contains("shape"), "{err}");
    }
}
