//! Activation/weight sparsity profiler — the data source for Fig. 3(a).
//!
//! The paper profiles the per-bit-index sparsity of a quantized network's
//! weights and *intermediate activations* (not input pixels). This module
//! wraps a [`MacBackend`] and records the bit-level sparsity of every
//! patch that flows into each compute layer, giving the true activation
//! profile as the CiM array sees it (im2col patches, zero-point padding
//! included — exactly the DP vectors of Eq. 1).

use super::exec::{GemmInput, MacBackend, RunStats};
use super::layers::{Model, Op};
use crate::pac::sparsity::bit_sparsity_counts;
use crate::tensor::{PackedPatches, Tensor};
use crate::util::Parallelism;
use std::sync::Mutex;

/// Accumulated per-layer sparsity statistics.
#[derive(Debug, Clone, Default)]
pub struct LayerProfile {
    pub name: String,
    /// Σ of bit counts over all observed activation elements.
    pub x_bit_counts: [u64; 8],
    /// Total activation elements observed.
    pub x_elems: u64,
    /// Weight bit counts (computed once at prepare).
    pub w_bit_counts: [u64; 8],
    pub w_elems: u64,
}

impl LayerProfile {
    /// Per-bit activation sparsity rates S_x[p]/n.
    pub fn x_rates(&self) -> [f64; 8] {
        let mut r = [0f64; 8];
        for p in 0..8 {
            r[p] = self.x_bit_counts[p] as f64 / self.x_elems.max(1) as f64;
        }
        r
    }

    /// Per-bit weight sparsity rates S_w[q]/n.
    pub fn w_rates(&self) -> [f64; 8] {
        let mut r = [0f64; 8];
        for p in 0..8 {
            r[p] = self.w_bit_counts[p] as f64 / self.w_elems.max(1) as f64;
        }
        r
    }
}

/// A backend wrapper that profiles activations flowing into `inner`.
pub struct ProfilingBackend<B> {
    inner: B,
    profiles: Mutex<Vec<LayerProfile>>,
}

impl<B: MacBackend> ProfilingBackend<B> {
    pub fn new(inner: B) -> Self {
        Self {
            inner,
            profiles: Mutex::new(Vec::new()),
        }
    }

    /// Attach layer names from the model (call after `prepare`s).
    pub fn name_layers(&self, model: &Model) {
        let mut profiles = self.profiles.lock().unwrap();
        let mut idx = 0;
        for op in &model.ops {
            let name = match op {
                Op::Conv2d(c) => Some(c.name.clone()),
                Op::Linear(l) => Some(l.name.clone()),
                _ => None,
            };
            if let Some(n) = name {
                if let Some(p) = profiles.get_mut(idx) {
                    p.name = n;
                }
                idx += 1;
            }
        }
    }

    /// Snapshot the accumulated profiles.
    pub fn profiles(&self) -> Vec<LayerProfile> {
        self.profiles.lock().unwrap().clone()
    }

    /// Aggregate activation sparsity across all profiled layers.
    pub fn aggregate_x_rates(&self) -> [f64; 8] {
        let profiles = self.profiles.lock().unwrap();
        let mut counts = [0u64; 8];
        let mut elems = 0u64;
        for p in profiles.iter() {
            for b in 0..8 {
                counts[b] += p.x_bit_counts[b];
            }
            elems += p.x_elems;
        }
        let mut r = [0f64; 8];
        for b in 0..8 {
            r[b] = counts[b] as f64 / elems.max(1) as f64;
        }
        r
    }

    /// Aggregate weight sparsity across all profiled layers.
    pub fn aggregate_w_rates(&self) -> [f64; 8] {
        let profiles = self.profiles.lock().unwrap();
        let mut counts = [0u64; 8];
        let mut elems = 0u64;
        for p in profiles.iter() {
            for b in 0..8 {
                counts[b] += p.w_bit_counts[b];
            }
            elems += p.w_elems;
        }
        let mut r = [0f64; 8];
        for b in 0..8 {
            r[b] = counts[b] as f64 / elems.max(1) as f64;
        }
        r
    }
}

impl<B: MacBackend> MacBackend for ProfilingBackend<B> {
    fn prepare(&mut self, layer_id: usize, weight: &Tensor<u8>, zpw: i32) {
        let counts = bit_sparsity_counts(weight.data());
        let mut profile = LayerProfile::default();
        for p in 0..8 {
            profile.w_bit_counts[p] = counts[p] as u64;
        }
        profile.w_elems = weight.numel() as u64;
        self.profiles.lock().unwrap().push(profile);
        self.inner.prepare(layer_id, weight, zpw);
    }

    /// The profiler is transparent to the encoded dataplane: fusion
    /// decisions are the wrapped backend's.
    fn packed_input_bits(&self, layer_id: usize) -> Option<u32> {
        self.inner.packed_input_bits(layer_id)
    }

    /// Transparent to fault injection too: the wrapped backend's model.
    fn fault(&self) -> Option<&crate::fault::FaultConfig> {
        self.inner.fault()
    }

    /// Residual skip-edge representation is the wrapped backend's call.
    fn fuse_residual(&self) -> bool {
        self.inner.fuse_residual()
    }

    #[allow(clippy::too_many_arguments)]
    fn gemm_layer(
        &self,
        layer_id: usize,
        input: GemmInput<'_>,
        pixels: usize,
        zpx: i32,
        nonce: u64,
        par: &Parallelism,
        planes: &mut PackedPatches,
        out: &mut Vec<i64>,
        stats: &mut RunStats,
    ) {
        // Per-bit counts over the whole patch matrix equal the sum of the
        // per-patch counts the pre-blocked profiler accumulated — one
        // pass, same profile. A producer-packed input already carries its
        // per-pixel sparsity counters, so the profiler reads those
        // instead of re-scanning bytes (identical totals by the packing
        // identity, property-tested in `tests/traffic.rs`).
        let (counts, elems) = match input {
            GemmInput::Dense(cols) => {
                let c = bit_sparsity_counts(cols);
                let mut counts = [0u64; 8];
                for b in 0..8 {
                    counts[b] = c[b] as u64;
                }
                (counts, cols.len() as u64)
            }
            GemmInput::Packed(x) => {
                let mut counts = [0u64; 8];
                for pix in 0..x.pixels() {
                    let pop = x.pop(pix);
                    for b in 0..8 {
                        counts[b] += pop[b] as u64;
                    }
                }
                (counts, (x.pixels() * x.k()) as u64)
            }
        };
        {
            let mut profiles = self.profiles.lock().unwrap();
            let p = &mut profiles[layer_id];
            for b in 0..8 {
                p.x_bit_counts[b] += counts[b];
            }
            p.x_elems += elems;
        }
        self.inner.gemm_layer(layer_id, input, pixels, zpx, nonce, par, planes, out, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::exec::{exact_backend, run_model_with, ExactBackend, ModelScratch};
    use crate::nn::layers::{synthetic, tiny_resnet};
    use crate::nn::pac_exec::{PacBackend, PacConfig};
    use crate::util::rng::Rng;

    fn run<B: MacBackend + Sync>(model: &Model, backend: &B, img: &[u8]) -> (Vec<f32>, RunStats) {
        run_model_with(model, backend, img, &Parallelism::off(), &mut ModelScratch::default())
            .unwrap()
    }

    fn prepare_wrapped<B: MacBackend>(prof: &mut ProfilingBackend<B>, model: &Model) {
        // Re-prepare through the wrapper so weights are profiled too.
        let mut id = 0;
        for op in &model.ops {
            match op {
                Op::Conv2d(c) => {
                    prof.prepare(id, &c.weight, c.wparams.zero_point);
                    id += 1;
                }
                Op::Linear(l) => {
                    prof.prepare(id, &l.weight, l.wparams.zero_point);
                    id += 1;
                }
                _ => {}
            }
        }
    }

    #[test]
    fn profiles_every_compute_layer() {
        let mut rng = Rng::new(500);
        let store = synthetic::random_store(&mut rng, 8, 10);
        let model = tiny_resnet(&store, 16, 10).unwrap();
        let mut prof = ProfilingBackend::new(ExactBackend::default());
        prepare_wrapped(&mut prof, &model);
        prof.name_layers(&model);
        let img: Vec<u8> = (0..3 * 16 * 16).map(|_| rng.below(256) as u8).collect();
        let (_, _) = run(&model, &prof, &img);
        let profiles = prof.profiles();
        assert_eq!(profiles.len(), 10); // 9 convs + fc
        assert_eq!(profiles[0].name, "stem");
        for p in &profiles {
            assert!(p.x_elems > 0, "{} saw no activations", p.name);
            assert!(p.w_elems > 0);
            let rates = p.x_rates();
            assert!(rates.iter().all(|&r| (0.0..=1.0).contains(&r)));
        }
    }

    #[test]
    fn profiling_does_not_change_results() {
        let mut rng = Rng::new(501);
        let store = synthetic::random_store(&mut rng, 8, 10);
        let model = tiny_resnet(&store, 16, 10).unwrap();
        let plain = exact_backend(&model);
        let mut prof = ProfilingBackend::new(ExactBackend::default());
        prepare_wrapped(&mut prof, &model);
        let img: Vec<u8> = (0..3 * 16 * 16).map(|_| rng.below(256) as u8).collect();
        let (a, _) = run(&model, &plain, &img);
        let (b, _) = run(&model, &prof, &img);
        assert_eq!(a, b);
    }

    #[test]
    fn packed_input_profiles_identically_to_dense() {
        // The encoded dataplane hands the profiler packed planes instead
        // of bytes; the sparsity counters must yield the exact same
        // per-layer profile (and the same logits) as the dense path.
        let mut rng = Rng::new(502);
        let store = synthetic::random_store(&mut rng, 8, 10);
        let model = tiny_resnet(&store, 16, 10).unwrap();
        let img: Vec<u8> = (0..3 * 16 * 16).map(|_| rng.below(256) as u8).collect();
        let cfg = |fuse| PacConfig {
            min_dp_len: 0,
            first_layer_exact: false,
            par: Parallelism::off(),
            fuse_dataplane: fuse,
            ..PacConfig::default()
        };
        let mut results = Vec::new();
        for fuse in [false, true] {
            let mut prof = ProfilingBackend::new(PacBackend::new(cfg(fuse)));
            prepare_wrapped(&mut prof, &model);
            let (logits, stats) = run(&model, &prof, &img);
            let encoded = stats.traffic.encoded_layer_count();
            assert_eq!(encoded > 0, fuse, "fuse={fuse} encoded={encoded}");
            results.push((logits, prof.profiles()));
        }
        let (a_logits, a_prof) = &results[0];
        let (b_logits, b_prof) = &results[1];
        assert_eq!(a_logits, b_logits);
        assert_eq!(a_prof.len(), b_prof.len());
        for (a, b) in a_prof.iter().zip(b_prof) {
            assert_eq!(a.x_bit_counts, b.x_bit_counts, "{}", a.name);
            assert_eq!(a.x_elems, b.x_elems);
        }
    }

    #[test]
    fn aggregate_rates_weighted_by_elements() {
        let mut prof = ProfilingBackend::new(ExactBackend::default());
        let w = Tensor::from_vec(&[1, 4], vec![255u8, 255, 255, 255]);
        prof.prepare(0, &w, 128);
        let mut stats = RunStats::default();
        // All-ones patch: every bit set.
        prof.gemm_layer(
            0,
            GemmInput::Dense(&[255, 255, 255, 255]),
            1,
            0,
            0,
            &Parallelism::off(),
            &mut PackedPatches::default(),
            &mut Vec::new(),
            &mut stats,
        );
        let x = prof.aggregate_x_rates();
        assert!(x.iter().all(|&r| (r - 1.0).abs() < 1e-12));
        let wr = prof.aggregate_w_rates();
        assert!(wr.iter().all(|&r| (r - 1.0).abs() < 1e-12));
    }
}
