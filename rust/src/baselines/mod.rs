//! Competing approximate-CiM methods (Table 1 / Fig. 3(c) / Table 4).
//!
//! The paper compares PAC against three published designs. We cannot
//! re-implement their silicon, so each is modeled *behaviorally* at the
//! binary-MAC-cycle level: the method observes one bit-plane dot product
//! (a popcount over a DP vector) and returns its hardware's estimate of
//! it. Noise magnitudes are calibrated to the error levels reported in
//! the respective papers — the quantity Table 1 tabulates — so what our
//! benches measure is the *consequence* of those error levels under a
//! common protocol, not a re-derivation of each circuit.
//!
//! | Model | Published basis | Cited error |
//! |---|---|---|
//! | [`ApproxAdderTree`] | DIMC, ISSCC'22 [29]: approximate arithmetic adder tree | 4.0 / 6.8 % RMSE |
//! | [`AnalogLsb`] | DIANA, ISSCC'22 [26]: analog core + ADC | 3.5–4.8 % error |
//! | [`OsaHcim`] | OSA-HCIM, ASP-DAC'24 [4]: hybrid w/ quantization error | 8.5 % RMSE |
//! | [`PacMethod`] | this work (Eq. 3) | 0.3–1.0 % RMSE |

use crate::pac::mac::{pcu_cycle, PcuRounding};
use crate::util::rng::Rng;
use crate::util::stats::Accumulator;
use crate::util::{and_popcount, pack_bits_u64};

/// A binary-MAC-cycle approximation method. Given the true bit vectors
/// (as the hardware's array sees them), produce the method's estimate of
/// the dot product `Σ x_n · w_n`.
pub trait CycleApprox {
    fn name(&self) -> &'static str;
    /// Estimate the DP of one cycle. `rng` supplies the method's internal
    /// noise source (analog noise, etc.) — deterministic per seed.
    fn estimate(&self, x: &[u8], w: &[u8], rng: &mut Rng) -> f64;
}

/// Exact digital reference (D-CiM): zero error by construction.
pub struct ExactDigital;

impl CycleApprox for ExactDigital {
    fn name(&self) -> &'static str {
        "D-CiM (exact)"
    }

    fn estimate(&self, x: &[u8], w: &[u8], _rng: &mut Rng) -> f64 {
        and_popcount(&pack_bits_u64(x), &pack_bits_u64(w)) as f64
    }
}

/// DIMC-style approximate adder tree [29]: the low carry chains of the
/// adder tree are cut, so the popcount loses its `trunc_bits` LSBs.
/// `trunc_bits` is calibrated per DP length to land at the cited
/// 4.0% (single-approximate) RMSE: truncation error is ~uniform over
/// [0, 2^t), σ = 2^t/√12 → t = log2(0.04·n·√12).
pub struct ApproxAdderTree {
    pub trunc_bits: u32,
}

impl ApproxAdderTree {
    /// Calibrate truncation depth for an `rmse_frac` (e.g. 0.04) target
    /// at DP length n.
    pub fn calibrated(n: usize, rmse_frac: f64) -> Self {
        let t = (rmse_frac * n as f64 * 12f64.sqrt()).log2().round();
        Self {
            trunc_bits: t.max(0.0) as u32,
        }
    }
}

impl CycleApprox for ApproxAdderTree {
    fn name(&self) -> &'static str {
        "Approx adder tree (DIMC'22)"
    }

    fn estimate(&self, x: &[u8], w: &[u8], _rng: &mut Rng) -> f64 {
        let exact = and_popcount(&pack_bits_u64(x), &pack_bits_u64(w));
        ((exact >> self.trunc_bits) << self.trunc_bits) as f64
    }
}

/// DIANA-style analog LSB path [26]: charge-domain accumulation read out
/// by an ADC. Modeled as ADC quantization over [0, n] at `adc_bits`
/// resolution plus Gaussian analog noise of `noise_frac·n` σ — the
/// combination calibrated to the 3.5–4.8% error band reported in [11].
pub struct AnalogLsb {
    pub adc_bits: u32,
    pub noise_frac: f64,
    pub dp_len: usize,
}

impl AnalogLsb {
    pub fn diana(dp_len: usize) -> Self {
        Self {
            adc_bits: 5,
            noise_frac: 0.033,
            dp_len,
        }
    }
}

impl CycleApprox for AnalogLsb {
    fn name(&self) -> &'static str {
        "Analog + ADC (DIANA'22)"
    }

    fn estimate(&self, x: &[u8], w: &[u8], rng: &mut Rng) -> f64 {
        let exact = and_popcount(&pack_bits_u64(x), &pack_bits_u64(w)) as f64;
        let noisy = exact + rng.gaussian(0.0, self.noise_frac * self.dp_len as f64);
        let step = self.dp_len as f64 / 2f64.powi(self.adc_bits as i32);
        (noisy / step).round().clamp(0.0, 2f64.powi(self.adc_bits as i32)) * step
    }
}

/// OSA-HCIM-style hybrid [4]: coarser analog path; the paper reports
/// 8.5% RMSE from macro spec + quantization error.
pub struct OsaHcim {
    pub dp_len: usize,
}

impl CycleApprox for OsaHcim {
    fn name(&self) -> &'static str {
        "Hybrid CiM (OSA-HCIM'24)"
    }

    fn estimate(&self, x: &[u8], w: &[u8], rng: &mut Rng) -> f64 {
        let exact = and_popcount(&pack_bits_u64(x), &pack_bits_u64(w)) as f64;
        let step = self.dp_len as f64 / 16.0; // 4b conversion
        let noisy = exact + rng.gaussian(0.0, 0.075 * self.dp_len as f64);
        (noisy / step).round().clamp(0.0, 16.0) * step
    }
}

/// This work: the PAC point estimate (Eq. 3) from the observed popcounts.
pub struct PacMethod {
    pub rounding: PcuRounding,
}

impl CycleApprox for PacMethod {
    fn name(&self) -> &'static str {
        "PAC (this work)"
    }

    fn estimate(&self, x: &[u8], w: &[u8], _rng: &mut Rng) -> f64 {
        let n = x.len() as u32;
        let sx: u32 = x.iter().map(|&b| b as u32).sum();
        let sw: u32 = w.iter().map(|&b| b as u32).sum();
        pcu_cycle(sx, sw, n.max(1), self.rounding) as f64
    }
}

/// Measure the RMSE (%) of a method over random bit vectors at the given
/// sparsity operating point — the common protocol behind Table 1 and
/// Fig. 3(c).
pub fn measure_rmse_pct(
    method: &dyn CycleApprox,
    n: usize,
    sparsity_x: f64,
    sparsity_w: f64,
    iterations: u64,
    seed: u64,
) -> f64 {
    let mut rng = Rng::new(seed);
    let mut err = Accumulator::new();
    for _ in 0..iterations {
        let x = rng.binary_bernoulli(n, sparsity_x);
        let w = rng.binary_bernoulli(n, sparsity_w);
        let exact = and_popcount(&pack_bits_u64(&x), &pack_bits_u64(&w)) as f64;
        let est = method.estimate(&x, &w, &mut rng);
        err.push(est - exact);
    }
    err.rms() / n as f64 * 100.0
}

#[cfg(test)]
mod tests {
    use super::*;

    const N: usize = 1024;
    const ITERS: u64 = 1500;

    #[test]
    fn exact_has_zero_error() {
        let r = measure_rmse_pct(&ExactDigital, N, 0.3, 0.5, 200, 1);
        assert_eq!(r, 0.0);
    }

    #[test]
    fn adder_tree_lands_near_cited_4pct() {
        let m = ApproxAdderTree::calibrated(N, 0.04);
        let r = measure_rmse_pct(&m, N, 0.3, 0.5, ITERS, 2);
        assert!((2.5..5.5).contains(&r), "rmse={r}%");
    }

    #[test]
    fn diana_lands_in_cited_band() {
        let m = AnalogLsb::diana(N);
        let r = measure_rmse_pct(&m, N, 0.3, 0.5, ITERS, 3);
        assert!((3.0..5.3).contains(&r), "rmse={r}%");
    }

    #[test]
    fn osa_lands_near_cited_8_5pct() {
        let m = OsaHcim { dp_len: N };
        let r = measure_rmse_pct(&m, N, 0.3, 0.5, ITERS, 4);
        assert!((6.5..10.5).contains(&r), "rmse={r}%");
    }

    #[test]
    fn pac_beats_all_by_4x() {
        // Table 1's headline: PAC ≈ 0.3–1.0% — a ≥4× improvement.
        let pac = measure_rmse_pct(
            &PacMethod {
                rounding: PcuRounding::RoundNearest,
            },
            N,
            0.3,
            0.5,
            ITERS,
            5,
        );
        assert!((0.2..1.0).contains(&pac), "pac={pac}%");
        let adder = measure_rmse_pct(&ApproxAdderTree::calibrated(N, 0.04), N, 0.3, 0.5, ITERS, 6);
        assert!(adder / pac >= 4.0, "adder={adder}% pac={pac}%");
    }

    #[test]
    fn pac_crossover_near_dp64() {
        // Fig. 3(c): PAC's RMSE crosses below the ≈4% competitor line at
        // DP length ≈ 64.
        let pac_32 = measure_rmse_pct(
            &PacMethod {
                rounding: PcuRounding::RoundNearest,
            },
            32,
            0.3,
            0.5,
            ITERS,
            7,
        );
        let pac_128 = measure_rmse_pct(
            &PacMethod {
                rounding: PcuRounding::RoundNearest,
            },
            128,
            0.3,
            0.5,
            ITERS,
            8,
        );
        assert!(pac_32 > 3.0, "pac@32={pac_32}%");
        assert!(pac_128 < 4.0, "pac@128={pac_128}%");
    }
}
