//! Seeded CiM fault models injected at the PAC boundaries (DESIGN.md
//! §15).
//!
//! PAC is a statistical estimator running on analog-adjacent hardware:
//! bit-cells flip at array-programming time, the PCU's sparsity
//! sampling is noisy, and the PR 5 encoded dataplane moves MSB planes
//! over real wires. This module injects all three error sources
//! deterministically so resilience experiments are replayable:
//!
//! - **Weight MSB-plane flips** (`weight_msb_ber`) — per-bit Bernoulli
//!   flips on the *digital* weight bit-planes at `PacBackend::prepare`
//!   time (array programming). The PCU's weight-sparsity registers and
//!   the zero-point correction sums keep their nominal values — the
//!   drift between the faulty array and the nominal counters is part of
//!   the injected error, exactly as on silicon.
//! - **PCU sampling-noise inflation** (`pcu_noise`) — additive Gaussian
//!   on each output's sparsity-domain partial sum, with
//!   `σ = pcu_noise · n` output LSB for DP length `n` (the `pac_rmse`
//!   %-of-DP convention).
//! - **Encoded-edge transmission flips** (`edge_ber`) — per-bit
//!   Bernoulli flips on the packed MSB planes of every sparsity-encoded
//!   inter-layer edge, applied after the producer packs and before the
//!   consumer sweeps.
//!
//! **Determinism contract.** Every draw is keyed by *position* — layer,
//! output channel, word index, plus a per-image content nonce for the
//! runtime channels — never by a shared sequential stream. Injection is
//! therefore bit-identical across tile schedules, lane fan-out, and
//! `Parallelism` on/off (property-tested in
//! `tests/fault_resilience.rs`). With [`FaultConfig::off`] no RNG is
//! constructed and no branch reorders work: runs are bit-identical to
//! an engine built without a fault config at all.

use crate::util::rng::Rng;

/// Domain tags keep the three fault channels' draws independent even
/// when they share (layer, position) keys.
pub(crate) const DOMAIN_WEIGHT: u64 = 0x57E1_6875;
pub(crate) const DOMAIN_EDGE: u64 = 0xED6E_F119;
pub(crate) const DOMAIN_PCU: u64 = 0x9C09_015E;

/// Seeded, deterministic CiM error model, configured on
/// [`crate::engine::EngineBuilder::fault`]. Default **off**: zero cost,
/// bit-identical to the fault-free engine.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultConfig {
    /// Master seed: same seed + same rates ⇒ identical injections,
    /// replayable across runs and machines.
    pub seed: u64,
    /// σ of the additive Gaussian on each PAC output's sparsity-domain
    /// partial sum, in units of the layer DP length (0 = off).
    pub pcu_noise: f64,
    /// Per-bit flip probability on the digital (MSB) weight planes at
    /// array-programming time (0 = off).
    pub weight_msb_ber: f64,
    /// Per-bit transmission flip probability on the packed MSB planes
    /// of sparsity-encoded inter-layer edges (0 = off).
    pub edge_ber: f64,
}

impl Default for FaultConfig {
    fn default() -> Self {
        Self::off()
    }
}

impl FaultConfig {
    /// The no-fault configuration: every channel disabled.
    pub const fn off() -> Self {
        Self { seed: 0x5EED_FA17, pcu_noise: 0.0, weight_msb_ber: 0.0, edge_ber: 0.0 }
    }

    /// All three channels driven at one bit-error rate (the sweep shape
    /// `pacim faultsweep` plots): both BER channels at `ber`, PCU noise
    /// at the same relative magnitude.
    pub fn at_ber(seed: u64, ber: f64) -> Self {
        Self { seed, pcu_noise: ber, weight_msb_ber: ber, edge_ber: ber }
    }

    /// True when no channel can ever inject.
    pub fn is_off(&self) -> bool {
        self.pcu_noise == 0.0 && self.weight_msb_ber == 0.0 && self.edge_ber == 0.0
    }

    /// Rates must be sane probabilities / scales; rejected at
    /// `EngineBuilder::build` with a typed error.
    pub fn validate(&self) -> Result<(), String> {
        for (name, p) in [("weight_msb_ber", self.weight_msb_ber), ("edge_ber", self.edge_ber)] {
            if !(p.is_finite() && (0.0..1.0).contains(&p)) {
                return Err(format!("fault {name} must be in [0, 1), got {p}"));
            }
        }
        if !(self.pcu_noise.is_finite() && self.pcu_noise >= 0.0) {
            return Err(format!("fault pcu_noise must be finite and ≥ 0, got {}", self.pcu_noise));
        }
        Ok(())
    }
}

/// SplitMix64 finalizer — a strong 64-bit mixer for position keys.
#[inline]
fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Position key: deterministic function of (seed, domain, a, b) with no
/// sequential state, so draws commute with any execution order.
#[inline]
pub(crate) fn key(seed: u64, domain: u64, a: u64, b: u64) -> u64 {
    let mut h = mix64(seed ^ 0x9E37_79B9_7F4A_7C15);
    h = mix64(h ^ domain);
    h = mix64(h ^ a);
    mix64(h ^ b)
}

/// A position-keyed RNG stream (see [`key`]); reuses [`crate::util::rng`]
/// so fault draws share the crate's replayability guarantees.
#[inline]
pub(crate) fn keyed_rng(seed: u64, domain: u64, a: u64, b: u64) -> Rng {
    Rng::new(key(seed, domain, a, b))
}

/// Content nonce for the runtime fault channels: transmission flips and
/// PCU noise must differ between images but stay independent of lane
/// index and tile schedule, so the key carries a hash of the input
/// image rather than any execution-order counter.
pub fn image_nonce(image: &[u8]) -> u64 {
    let mut h = 0xCBF2_9CE4_8422_2325u64;
    for &b in image {
        h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01B3);
    }
    mix64(h)
}

/// Per-bit Bernoulli flip mask over the low `valid_bits` of one packed
/// word (tail-word padding is never flipped: pad bits must stay zero so
/// the popcount sweeps see no phantom dot-product taps).
pub(crate) fn flip_mask(rng: &mut Rng, ber: f64, valid_bits: u32) -> u64 {
    debug_assert!(valid_bits <= 64);
    let mut mask = 0u64;
    for bit in 0..valid_bits {
        if rng.bernoulli(ber) {
            mask |= 1u64 << bit;
        }
    }
    mask
}

/// Flip transmission bits on a sparsity-encoded conv→conv edge: every
/// transmitted MSB plane word of every pixel draws a position-keyed
/// Bernoulli mask at `cfg.edge_ber`. Returns the number of bits
/// flipped. Only the top `msb_bits` planes are touched — those are the
/// payload the PR 5 edge actually moves — and tail-word padding past
/// `k` is never flipped (the zero-tail invariant the popcount sweeps
/// rely on). The per-pixel sparsity counters are left at the values the
/// producer shipped: on the wire, planes and counters are separate
/// payloads, and the drift between them is part of the injected error.
pub(crate) fn flip_encoded_edge(
    cfg: &FaultConfig,
    packed: &mut crate::tensor::PackedPatches,
    layer_id: usize,
    nonce: u64,
    msb_bits: u32,
) -> u64 {
    if cfg.edge_ber <= 0.0 || msb_bits == 0 {
        return 0;
    }
    let (pixels, k, words) = (packed.pixels(), packed.k(), packed.words());
    if words == 0 {
        return 0;
    }
    let tail_bits = (k - (words - 1) * 64) as u32;
    let planes = packed.planes_mut();
    let mut flipped = 0u64;
    for pix in 0..pixels {
        for p in (8 - msb_bits as usize)..8 {
            let base = (pix * 8 + p) * words;
            for w in 0..words {
                let valid = if w + 1 == words { tail_bits } else { 64 };
                let a = nonce ^ ((layer_id as u64) << 40) ^ (pix as u64);
                let b = ((p as u64) << 32) | w as u64;
                let mask =
                    flip_mask(&mut keyed_rng(cfg.seed, DOMAIN_EDGE, a, b), cfg.edge_ber, valid);
                planes[base + w] ^= mask;
                flipped += mask.count_ones() as u64;
            }
        }
    }
    flipped
}

/// Per-layer injection counters, surfaced through
/// [`crate::nn::RunStats`] so every run reports exactly what was
/// injected where. Integer-only and merged in layer order: bit-identical
/// across par on/off like every other stat.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LayerFaults {
    /// Interpreter layer id the injections hit.
    pub layer_id: usize,
    /// Weight MSB-plane bits flipped at array-programming time (counted
    /// once per `gemm_layer` call so per-image runs stay comparable).
    pub weight_bits_flipped: u64,
    /// Encoded-edge plane bits flipped in transmission.
    pub edge_bits_flipped: u64,
    /// Outputs whose sparsity-domain sum received PCU noise.
    pub pcu_noise_events: u64,
}

/// Ledger of [`LayerFaults`] rows, ordered by layer id (mirrors
/// `memory::TrafficLedger`).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultLedger {
    layers: Vec<LayerFaults>,
}

impl FaultLedger {
    fn entry(&mut self, layer_id: usize) -> &mut LayerFaults {
        let idx = match self.layers.binary_search_by_key(&layer_id, |l| l.layer_id) {
            Ok(i) => i,
            Err(i) => {
                self.layers.insert(i, LayerFaults { layer_id, ..LayerFaults::default() });
                i
            }
        };
        &mut self.layers[idx]
    }

    /// Record weight-plane flips active for `layer_id` this run.
    pub fn record_weight(&mut self, layer_id: usize, bits: u64) {
        self.entry(layer_id).weight_bits_flipped += bits;
    }

    /// Record transmission flips on the encoded edge out of `layer_id`.
    pub fn record_edge(&mut self, layer_id: usize, bits: u64) {
        self.entry(layer_id).edge_bits_flipped += bits;
    }

    /// Record PCU-noise injections on `layer_id`'s outputs.
    pub fn record_pcu(&mut self, layer_id: usize, events: u64) {
        self.entry(layer_id).pcu_noise_events += events;
    }

    /// Fold another ledger in (same layer ids add; new ids insert in
    /// order — deterministic regardless of merge order).
    pub fn merge(&mut self, other: &FaultLedger) {
        for l in &other.layers {
            let e = self.entry(l.layer_id);
            e.weight_bits_flipped += l.weight_bits_flipped;
            e.edge_bits_flipped += l.edge_bits_flipped;
            e.pcu_noise_events += l.pcu_noise_events;
        }
    }

    /// Per-layer rows, ordered by layer id.
    pub fn layers(&self) -> &[LayerFaults] {
        &self.layers
    }

    /// No injections recorded at all.
    pub fn is_empty(&self) -> bool {
        self.layers.is_empty()
    }

    /// Total weight-plane bits flipped across layers.
    pub fn total_weight_bits(&self) -> u64 {
        self.layers.iter().map(|l| l.weight_bits_flipped).sum()
    }

    /// Total encoded-edge bits flipped across layers.
    pub fn total_edge_bits(&self) -> u64 {
        self.layers.iter().map(|l| l.edge_bits_flipped).sum()
    }

    /// Total PCU-noise injection events across layers.
    pub fn total_pcu_events(&self) -> u64 {
        self.layers.iter().map(|l| l.pcu_noise_events).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn off_is_off_and_valid() {
        let f = FaultConfig::off();
        assert!(f.is_off());
        f.validate().unwrap();
        assert_eq!(FaultConfig::default(), f);
    }

    #[test]
    fn bad_rates_rejected() {
        let mut f = FaultConfig::off();
        f.weight_msb_ber = 1.5;
        assert!(f.validate().is_err());
        f = FaultConfig::off();
        f.edge_ber = -0.1;
        assert!(f.validate().is_err());
        f = FaultConfig::off();
        f.pcu_noise = f64::NAN;
        assert!(f.validate().is_err());
        FaultConfig::at_ber(1, 1e-3).validate().unwrap();
    }

    #[test]
    fn keys_are_position_determined() {
        assert_eq!(key(1, DOMAIN_EDGE, 2, 3), key(1, DOMAIN_EDGE, 2, 3));
        assert_ne!(key(1, DOMAIN_EDGE, 2, 3), key(1, DOMAIN_WEIGHT, 2, 3));
        assert_ne!(key(1, DOMAIN_PCU, 2, 3), key(1, DOMAIN_PCU, 3, 2));
        assert_ne!(key(1, DOMAIN_PCU, 2, 3), key(2, DOMAIN_PCU, 2, 3));
    }

    #[test]
    fn flip_mask_respects_valid_bits_and_rate() {
        let mut rng = keyed_rng(7, DOMAIN_EDGE, 0, 0);
        assert_eq!(flip_mask(&mut rng, 1.0, 40), (1u64 << 40) - 1);
        let mut rng = keyed_rng(7, DOMAIN_EDGE, 0, 1);
        assert_eq!(flip_mask(&mut rng, 0.0, 64), 0);
        // ~half the bits at p = 0.5, and replayable.
        let a = flip_mask(&mut keyed_rng(9, DOMAIN_EDGE, 4, 2), 0.5, 64);
        let b = flip_mask(&mut keyed_rng(9, DOMAIN_EDGE, 4, 2), 0.5, 64);
        assert_eq!(a, b);
        assert!((10..54).contains(&a.count_ones()));
    }

    #[test]
    fn nonce_depends_on_content() {
        assert_eq!(image_nonce(&[1, 2, 3]), image_nonce(&[1, 2, 3]));
        assert_ne!(image_nonce(&[1, 2, 3]), image_nonce(&[1, 2, 4]));
        assert_ne!(image_nonce(&[]), image_nonce(&[0]));
    }

    #[test]
    fn ledger_merges_in_layer_order() {
        let mut a = FaultLedger::default();
        a.record_weight(2, 5);
        a.record_edge(0, 3);
        let mut b = FaultLedger::default();
        b.record_weight(2, 7);
        b.record_pcu(1, 10);
        a.merge(&b);
        let ids: Vec<usize> = a.layers().iter().map(|l| l.layer_id).collect();
        assert_eq!(ids, vec![0, 1, 2]);
        assert_eq!(a.total_weight_bits(), 12);
        assert_eq!(a.total_edge_bits(), 3);
        assert_eq!(a.total_pcu_events(), 10);
        assert!(!a.is_empty());
        assert!(FaultLedger::default().is_empty());
    }
}
