//! Measured activation-traffic ledger — the workload-measured
//! counterpart of the analytic [`super::traffic`] model.
//!
//! The analytic model (Fig. 7(b)) predicts the traffic of an encoding
//! scheme from layer geometry alone, assuming every edge is encoded.
//! The ledger instead records what the executor *actually moved* while
//! running a network: the interpreter appends one entry per inter-layer
//! activation edge as it emits it, tagged with the real encode decision
//! (producer-packed MSB+counter form vs dense u8), the real group count
//! (output pixels), the real channel width, and the *kind* of consumer
//! the edge feeds ([`EdgeKind`]). Exact-mode layers, first-layer and
//! short-DP digital fallbacks, and unfusable program points (pooling,
//! the logits head) therefore show up as the dense edges they are — the
//! honesty that closed-form traffic claims lack.
//!
//! A residual block contributes three edges per pass: the producer's
//! write into the skip slot ([`EdgeKind::ResidualSave`]), the in-block
//! tail conv's operand hand-off into the add ([`EdgeKind::ResidualIn`] —
//! *eliminated* when the add is fused into that conv's requantize step,
//! recorded via [`TrafficLedger::record_eliminated`] with zero measured
//! bits against the full dense baseline), and the post-add activation
//! flowing on to the next consumer ([`EdgeKind::ResidualAdd`]).
//!
//! Units: one entry's `bits` is the producer's write; the consumer read
//! mirrors it under the paper's write-once/read-once cache model, so
//! total cache traffic is `2 × bits` (the convention
//! `coordinator::scheduler::LayerReport` also uses). The final logits
//! layer is delivered to the host, not written back to the activation
//! cache, and is not recorded.

use super::traffic::activation_traffic;

/// What the consumer side of an inter-layer edge is — the class of op
/// that reads the producer's write. One compute layer can emit several
/// edges of different kinds (a residual tail conv writes both the add
/// operand and, post-add, the next layer's input), so ledger entries
/// are keyed by `(layer_id, kind)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EdgeKind {
    /// Straight conv→conv activation edge.
    Conv,
    /// Edge into a (hidden) linear layer.
    Linear,
    /// Edge into a pooling op (max pool / global average pool).
    Pool,
    /// Producer write into a residual skip slot (`SaveSkip`).
    ResidualSave,
    /// In-block tail conv → `AddSkip` operand; eliminated (zero bits)
    /// when the add is fused into the producing conv's epilogue.
    ResidualIn,
    /// Post-`AddSkip` activation flowing to the next consumer.
    ResidualAdd,
}

impl EdgeKind {
    /// Stable lower-snake name, used by the bench schema and printouts.
    pub fn as_str(&self) -> &'static str {
        match self {
            EdgeKind::Conv => "conv",
            EdgeKind::Linear => "linear",
            EdgeKind::Pool => "pool",
            EdgeKind::ResidualSave => "residual_save",
            EdgeKind::ResidualIn => "residual_in",
            EdgeKind::ResidualAdd => "residual_add",
        }
    }
}

/// Measured traffic of one inter-layer activation edge, accumulated
/// over every forward pass merged into the owning ledger.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LayerTraffic {
    /// Producer compute-layer id (prepare order); join with
    /// `Model::compute_layers()` for names.
    pub layer_id: usize,
    /// Consumer class of this edge (one layer may emit several kinds).
    pub kind: EdgeKind,
    /// Forward passes accumulated into this entry.
    pub runs: u64,
    /// Encoding groups moved (one output pixel per group for CONV, one
    /// per layer for LINEAR), summed over runs.
    pub groups: u64,
    /// Channels per encoding group (constant per layer).
    pub group_elems: u64,
    /// Binary MSB planes transmitted per element when encoded (0 on
    /// dense edges *and* on eliminated edges).
    pub msb_bits: u32,
    /// Whether this edge moved in MSB+counter form (or, with
    /// `msb_bits == 0`, was eliminated outright by fusion).
    pub encoded: bool,
    /// Measured bits moved, one direction (producer write).
    pub bits: u64,
    /// 8-bit dense equivalent of the same elements.
    pub baseline_bits: u64,
}

impl LayerTraffic {
    /// Activation elements moved (groups × channels).
    pub fn elems(&self) -> u64 {
        self.groups * self.group_elems
    }

    /// A fused-away edge: nothing moved at all (the add was folded into
    /// the producing conv's requantize step), against a real baseline.
    pub fn is_eliminated(&self) -> bool {
        self.encoded && self.msb_bits == 0
    }

    /// Fractional reduction vs the 8-bit dense baseline (0 on dense
    /// edges; 1 on eliminated edges; can be negative when counter
    /// overhead exceeds the LSB saving — the crossover the analytic
    /// model also exposes, and what the 8-plane `ResidualSave` edge
    /// shows on narrow layers).
    pub fn reduction(&self) -> f64 {
        1.0 - self.bits as f64 / self.baseline_bits.max(1) as f64
    }
}

/// Running per-(layer, kind) tally of measured activation traffic;
/// lives in [`crate::nn::RunStats`] and merges like the other counters.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TrafficLedger {
    layers: Vec<LayerTraffic>,
}

impl TrafficLedger {
    /// Record a dense u8 edge: `groups × group_elems` activations moved
    /// at 8 bits each.
    pub fn record_dense(&mut self, layer_id: usize, kind: EdgeKind, groups: u64, group_elems: u64) {
        let bits = groups * group_elems * 8;
        self.record(LayerTraffic {
            layer_id,
            kind,
            runs: 1,
            groups,
            group_elems,
            msb_bits: 0,
            encoded: false,
            bits,
            baseline_bits: bits,
        });
    }

    /// Record a sparsity-encoded edge: per group, `group_elems`
    /// activations travel as `msb_bits` binary planes plus 8 sparsity
    /// counters of `⌈log2(group_elems)⌉` bits (§3.1 data encoding).
    pub fn record_encoded(
        &mut self,
        layer_id: usize,
        kind: EdgeKind,
        groups: u64,
        group_elems: u64,
        msb_bits: u32,
    ) {
        let (bits, baseline_bits) = if groups == 0 || group_elems == 0 {
            (0, 0)
        } else {
            let t = activation_traffic(group_elems as usize, msb_bits);
            (groups * t.pacim, groups * t.baseline)
        };
        self.record(LayerTraffic {
            layer_id,
            kind,
            runs: 1,
            groups,
            group_elems,
            msb_bits,
            encoded: true,
            bits,
            baseline_bits,
        });
    }

    /// Record an edge the fused dataplane eliminated outright: the
    /// residual-add operand consumed inside the producing conv's
    /// epilogue. Zero bits move; the baseline stays the dense tensor
    /// the round-trip path would have written.
    pub fn record_eliminated(
        &mut self,
        layer_id: usize,
        kind: EdgeKind,
        groups: u64,
        group_elems: u64,
    ) {
        self.record(LayerTraffic {
            layer_id,
            kind,
            runs: 1,
            groups,
            group_elems,
            msb_bits: 0,
            encoded: true,
            bits: 0,
            baseline_bits: groups * group_elems * 8,
        });
    }

    fn record(&mut self, e: LayerTraffic) {
        let key = |l: &LayerTraffic| (l.layer_id, l.kind);
        if let Some(cur) = self.layers.iter_mut().find(|l| key(l) == key(&e)) {
            debug_assert_eq!(
                (cur.encoded, cur.msb_bits, cur.group_elems),
                (e.encoded, e.msb_bits, e.group_elems),
                "layer {} edge {:?} changed encoding between runs",
                e.layer_id,
                e.kind
            );
            cur.runs += e.runs;
            cur.groups += e.groups;
            cur.bits += e.bits;
            cur.baseline_bits += e.baseline_bits;
        } else {
            self.layers.push(e);
        }
    }

    /// Fold another ledger in (same program ⇒ entries align by
    /// (layer id, kind); per-entry counters sum).
    pub fn merge(&mut self, other: &TrafficLedger) {
        for e in &other.layers {
            self.record(*e);
        }
    }

    /// Entries in first-recorded (= program) order.
    pub fn layers(&self) -> &[LayerTraffic] {
        &self.layers
    }

    /// The first entry for one compute layer, if it moved activations
    /// (layers with several edge kinds: see [`Self::row`]).
    pub fn layer(&self, layer_id: usize) -> Option<&LayerTraffic> {
        self.layers.iter().find(|l| l.layer_id == layer_id)
    }

    /// The entry for one (layer, kind) edge, if recorded.
    pub fn row(&self, layer_id: usize, kind: EdgeKind) -> Option<&LayerTraffic> {
        self.layers
            .iter()
            .find(|l| l.layer_id == layer_id && l.kind == kind)
    }

    /// Edges that moved in MSB+counter form (or were eliminated).
    pub fn encoded_layer_count(&self) -> usize {
        self.layers.iter().filter(|l| l.encoded).count()
    }

    /// Total measured bits moved, one direction.
    pub fn total_bits(&self) -> u64 {
        self.layers.iter().map(|l| l.bits).sum()
    }

    /// Total 8-bit dense-equivalent bits.
    pub fn total_baseline_bits(&self) -> u64 {
        self.layers.iter().map(|l| l.baseline_bits).sum()
    }

    /// Whole-network measured reduction vs the 8-bit dense baseline.
    pub fn reduction(&self) -> f64 {
        1.0 - self.total_bits() as f64 / self.total_baseline_bits().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pac::sparsity::counter_bits;

    #[test]
    fn dense_edge_is_8_bits_per_element() {
        let mut t = TrafficLedger::default();
        t.record_dense(0, EdgeKind::Conv, 16, 64);
        let e = t.layer(0).unwrap();
        assert!(!e.encoded);
        assert_eq!(e.kind, EdgeKind::Conv);
        assert_eq!(e.bits, 16 * 64 * 8);
        assert_eq!(e.baseline_bits, e.bits);
        assert_eq!(e.reduction(), 0.0);
    }

    #[test]
    fn encoded_edge_matches_analytic_formula() {
        let mut t = TrafficLedger::default();
        t.record_encoded(3, EdgeKind::Conv, 16, 256, 4);
        let e = t.layer(3).unwrap();
        assert!(e.encoded);
        assert!(!e.is_eliminated());
        assert_eq!(e.baseline_bits, 16 * 256 * 8);
        assert_eq!(e.bits, 16 * (256 * 4 + 8 * counter_bits(256) as u64));
        // 256-channel groups sit in the paper's deep-layer band.
        assert!((0.40..0.52).contains(&e.reduction()), "{}", e.reduction());
    }

    #[test]
    fn eliminated_edge_moves_nothing_against_a_dense_baseline() {
        let mut t = TrafficLedger::default();
        t.record_eliminated(2, EdgeKind::ResidualIn, 64, 16);
        let e = t.row(2, EdgeKind::ResidualIn).unwrap();
        assert!(e.encoded && e.is_eliminated());
        assert_eq!(e.bits, 0);
        assert_eq!(e.baseline_bits, 64 * 16 * 8);
        assert_eq!(e.reduction(), 1.0);
        assert_eq!(t.encoded_layer_count(), 1);
    }

    #[test]
    fn same_layer_different_kinds_are_separate_rows() {
        // A residual tail conv writes its add operand (eliminated) and,
        // post-add, the next layer's encoded input — two rows, one id.
        let mut t = TrafficLedger::default();
        t.record_eliminated(5, EdgeKind::ResidualIn, 64, 32);
        t.record_encoded(5, EdgeKind::ResidualAdd, 64, 32, 4);
        assert_eq!(t.layers().len(), 2);
        assert!(t.row(5, EdgeKind::ResidualIn).unwrap().is_eliminated());
        assert!(!t.row(5, EdgeKind::ResidualAdd).unwrap().is_eliminated());
        // Merging a second pass accumulates per (layer, kind).
        let copy = t.clone();
        t.merge(&copy);
        assert_eq!(t.layers().len(), 2);
        assert_eq!(t.row(5, EdgeKind::ResidualAdd).unwrap().runs, 2);
    }

    #[test]
    fn merge_accumulates_per_layer() {
        let mut a = TrafficLedger::default();
        a.record_dense(0, EdgeKind::Conv, 4, 8);
        a.record_encoded(1, EdgeKind::Conv, 4, 64, 4);
        let mut b = TrafficLedger::default();
        b.record_dense(0, EdgeKind::Conv, 4, 8);
        b.record_encoded(1, EdgeKind::Conv, 4, 64, 4);
        a.merge(&b);
        assert_eq!(a.layers().len(), 2);
        assert_eq!(a.layer(0).unwrap().runs, 2);
        assert_eq!(a.layer(0).unwrap().groups, 8);
        assert_eq!(a.layer(1).unwrap().bits, 2 * 4 * (64 * 4 + 8 * 6));
        assert_eq!(a.encoded_layer_count(), 1);
    }

    #[test]
    fn network_reduction_weights_by_bits() {
        let mut t = TrafficLedger::default();
        t.record_dense(0, EdgeKind::Conv, 1, 1000); // 8000 bits both
        t.record_encoded(1, EdgeKind::Conv, 1, 1000, 4); // 4000 + 80 bits vs 8000
        let red = t.reduction();
        let want = 1.0 - (8000.0 + 4080.0) / 16000.0;
        assert!((red - want).abs() < 1e-12, "{red} vs {want}");
    }

    #[test]
    fn degenerate_groups_record_zero_bits() {
        let mut t = TrafficLedger::default();
        t.record_encoded(0, EdgeKind::Conv, 0, 64, 4);
        t.record_encoded(1, EdgeKind::Conv, 4, 0, 4);
        assert_eq!(t.total_bits(), 0);
        assert_eq!(t.total_baseline_bits(), 0);
    }
}
