//! Analytic activation/weight traffic for baseline CiM vs PACiM
//! (Fig. 7(b) and the 40–50% memory-access-reduction claim).
//!
//! Baseline: every output activation is written to cache as 8 bits and
//! read back 8 bits for the next layer (per channel).
//!
//! PACiM: only the 4 MSBs travel in binary form; the on-die encoder
//! appends, per encoding group (a pixel across its channels for CONV,
//! the whole layer for LINEAR), 8 sparsity counters of ⌈log2(C)⌉ bits.
//! All 8 bit indices are encoded — the LSB counters feed the PAC units,
//! the full set feeds the SPEC speculation (Eq. 5) and the zero-point
//! correction.

use crate::pac::sparsity::counter_bits;

/// Bits moved for one encoding group (e.g. one output pixel across C
/// channels), one direction.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TrafficBits {
    pub baseline: u64,
    pub pacim: u64,
}

impl TrafficBits {
    /// Fractional reduction (positive = PACiM moves fewer bits).
    pub fn reduction(&self) -> f64 {
        1.0 - self.pacim as f64 / self.baseline as f64
    }
}

/// Activation traffic per encoding group of `channels` 8-bit activations,
/// with `msb_bits` transmitted in binary (paper default 4).
pub fn activation_traffic(channels: usize, msb_bits: u32) -> TrafficBits {
    assert!(channels > 0);
    let baseline = channels as u64 * 8;
    let counters = 8 * counter_bits(channels) as u64;
    let pacim = channels as u64 * msb_bits as u64 + counters;
    TrafficBits { baseline, pacim }
}

/// Weight traffic per DP group of `dp_len` 8-bit weights loaded from
/// DRAM: PACiM stores 4-bit MSB weights + offline-encoded sparsity.
pub fn weight_traffic(dp_len: usize, msb_bits: u32) -> TrafficBits {
    assert!(dp_len > 0);
    let baseline = dp_len as u64 * 8;
    let counters = 8 * counter_bits(dp_len) as u64;
    let pacim = dp_len as u64 * msb_bits as u64 + counters;
    TrafficBits { baseline, pacim }
}

/// Fig. 7(b) sweep: activation cache-access reduction vs channel count.
pub fn reduction_vs_channels(channels: &[usize], msb_bits: u32) -> Vec<(usize, f64)> {
    channels
        .iter()
        .map(|&c| (c, activation_traffic(c, msb_bits).reduction()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_64_channel_point() {
        // Fig. 7(b): at channel length 64 the reduction is ≈40%.
        let t = activation_traffic(64, 4);
        let r = t.reduction();
        assert!((0.37..0.45).contains(&r), "reduction={r}");
    }

    #[test]
    fn deep_layers_approach_50pct() {
        // Fig. 7(b): up to 50% in deeper CONV/LINEAR layers.
        let t = activation_traffic(2048, 4);
        assert!(t.reduction() > 0.47, "reduction={}", t.reduction());
        // Asymptote is exactly 50% (4 of 8 bits).
        let t = activation_traffic(1 << 20, 4);
        assert!((t.reduction() - 0.5).abs() < 0.01);
    }

    #[test]
    fn reduction_monotone_in_channels() {
        let rs = reduction_vs_channels(&[16, 32, 64, 128, 256, 512, 1024], 4);
        for w in rs.windows(2) {
            assert!(w[1].1 >= w[0].1, "{:?}", rs);
        }
    }

    #[test]
    fn small_channel_counts_can_lose() {
        // With very few channels the counter overhead can exceed the LSB
        // saving — the encoder would be configured off; we only assert the
        // model exposes this crossover (traffic math is honest).
        let t = activation_traffic(8, 4);
        assert!(t.pacim as f64 > t.baseline as f64 * 0.5);
    }

    #[test]
    fn weight_traffic_nearly_halves() {
        // §4.2: weight DRAM access reduced ≈50% (4-bit MSB storage).
        let t = weight_traffic(1152, 4); // 3×3×128 CONV kernel
        assert!((0.45..0.51).contains(&t.reduction()), "{}", t.reduction());
    }

    #[test]
    fn five_bit_mode() {
        // 5-bit approximation (for ImageNet-class accuracy) still saves.
        let t = activation_traffic(512, 5);
        assert!((0.30..0.40).contains(&t.reduction()), "{}", t.reduction());
    }
}
