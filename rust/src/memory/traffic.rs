//! Analytic activation/weight traffic for baseline CiM vs PACiM
//! (Fig. 7(b) and the 40–50% memory-access-reduction claim).
//!
//! Baseline: every output activation is written to cache as 8 bits and
//! read back 8 bits for the next layer (per channel).
//!
//! PACiM: only the 4 MSBs travel in binary form; the on-die encoder
//! appends, per encoding group (a pixel across its channels for CONV,
//! the whole layer for LINEAR), 8 sparsity counters of ⌈log2(C)⌉ bits.
//! All 8 bit indices are encoded — the LSB counters feed the PAC units,
//! the full set feeds the SPEC speculation (Eq. 5) and the zero-point
//! correction.

use crate::pac::sparsity::counter_bits;

/// Bits moved for one encoding group (e.g. one output pixel across C
/// channels), one direction.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TrafficBits {
    pub baseline: u64,
    pub pacim: u64,
}

impl TrafficBits {
    /// Fractional reduction (positive = PACiM moves fewer bits).
    pub fn reduction(&self) -> f64 {
        1.0 - self.pacim as f64 / self.baseline as f64
    }
}

/// Activation traffic per encoding group of `channels` 8-bit activations,
/// with `msb_bits` transmitted in binary (paper default 4).
pub fn activation_traffic(channels: usize, msb_bits: u32) -> TrafficBits {
    assert!(channels > 0);
    let baseline = channels as u64 * 8;
    let counters = 8 * counter_bits(channels) as u64;
    let pacim = channels as u64 * msb_bits as u64 + counters;
    TrafficBits { baseline, pacim }
}

/// Weight traffic per DP group of `dp_len` 8-bit weights loaded from
/// DRAM: PACiM stores 4-bit MSB weights + offline-encoded sparsity.
pub fn weight_traffic(dp_len: usize, msb_bits: u32) -> TrafficBits {
    assert!(dp_len > 0);
    let baseline = dp_len as u64 * 8;
    let counters = 8 * counter_bits(dp_len) as u64;
    let pacim = dp_len as u64 * msb_bits as u64 + counters;
    TrafficBits { baseline, pacim }
}

/// Closed-form traffic of one residual block's three inter-layer edges
/// under the fused dataplane vs the dense round-trip (the analytic
/// counterpart of the ledger's `ResidualSave`/`ResidualIn`/`ResidualAdd`
/// rows, one direction, for `pixels` encoding groups of `channels`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ResidualTraffic {
    /// Producer write into the skip slot. Fused, the slot stores packed
    /// planes: the add needs the exact u8 operand back, so all 8 planes
    /// travel plus the counters — slightly *above* the dense baseline
    /// (the honest cost of keeping the operand in encoded form).
    pub save: TrafficBits,
    /// In-block tail conv → add operand: eliminated outright when the
    /// add is fused into that conv's requantize step (`pacim = 0`).
    pub add_in: TrafficBits,
    /// Post-add activation to the next consumer, encoded at `msb_bits`
    /// planes (callers model this edge dense when the next consumer
    /// cannot take packed input, e.g. a pooling head).
    pub add_out: TrafficBits,
}

impl ResidualTraffic {
    /// Whole-block totals across the three edges.
    pub fn total(&self) -> TrafficBits {
        TrafficBits {
            baseline: self.save.baseline + self.add_in.baseline + self.add_out.baseline,
            pacim: self.save.pacim + self.add_in.pacim + self.add_out.pacim,
        }
    }
}

/// Analytic residual-block edge traffic for `pixels` groups of
/// `channels` activations with `msb_bits` MSB planes on the post-add
/// edge. For every `C ≥ 2` the fused block moves strictly fewer total
/// bits than the dense round-trip: the save edge's counter overhead
/// (`8·⌈log2 C⌉` per group) is strictly smaller than the eliminated
/// add-in edge (`8·C` per group). At `C = 1` the counters dominate and
/// the block honestly loses — the math exposes the crossover rather
/// than hiding it.
pub fn residual_traffic(channels: usize, pixels: u64, msb_bits: u32) -> ResidualTraffic {
    let per_group_save = activation_traffic(channels, 8);
    let per_group_add = activation_traffic(channels, msb_bits);
    let dense = channels as u64 * 8;
    ResidualTraffic {
        save: TrafficBits {
            baseline: pixels * per_group_save.baseline,
            pacim: pixels * per_group_save.pacim,
        },
        add_in: TrafficBits {
            baseline: pixels * dense,
            pacim: 0,
        },
        add_out: TrafficBits {
            baseline: pixels * per_group_add.baseline,
            pacim: pixels * per_group_add.pacim,
        },
    }
}

/// Fig. 7(b) sweep: activation cache-access reduction vs channel count.
pub fn reduction_vs_channels(channels: &[usize], msb_bits: u32) -> Vec<(usize, f64)> {
    channels
        .iter()
        .map(|&c| (c, activation_traffic(c, msb_bits).reduction()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_64_channel_point() {
        // Fig. 7(b): at channel length 64 the reduction is ≈40%.
        let t = activation_traffic(64, 4);
        let r = t.reduction();
        assert!((0.37..0.45).contains(&r), "reduction={r}");
    }

    #[test]
    fn deep_layers_approach_50pct() {
        // Fig. 7(b): up to 50% in deeper CONV/LINEAR layers.
        let t = activation_traffic(2048, 4);
        assert!(t.reduction() > 0.47, "reduction={}", t.reduction());
        // Asymptote is exactly 50% (4 of 8 bits).
        let t = activation_traffic(1 << 20, 4);
        assert!((t.reduction() - 0.5).abs() < 0.01);
    }

    #[test]
    fn reduction_monotone_in_channels() {
        let rs = reduction_vs_channels(&[16, 32, 64, 128, 256, 512, 1024], 4);
        for w in rs.windows(2) {
            assert!(w[1].1 >= w[0].1, "{:?}", rs);
        }
    }

    #[test]
    fn small_channel_counts_can_lose() {
        // With very few channels the counter overhead can exceed the LSB
        // saving — the encoder would be configured off; we only assert the
        // model exposes this crossover (traffic math is honest).
        let t = activation_traffic(8, 4);
        assert!(t.pacim as f64 > t.baseline as f64 * 0.5);
    }

    #[test]
    fn weight_traffic_nearly_halves() {
        // §4.2: weight DRAM access reduced ≈50% (4-bit MSB storage).
        let t = weight_traffic(1152, 4); // 3×3×128 CONV kernel
        assert!((0.45..0.51).contains(&t.reduction()), "{}", t.reduction());
    }

    #[test]
    fn residual_block_saves_at_every_width() {
        // The save edge alone costs more than dense (8 planes + counter
        // overhead), but the eliminated add-in edge pays for it: net
        // saving at every channel width from 2 up.
        for c in [2usize, 4, 8, 16, 64, 128, 256, 512] {
            let r = residual_traffic(c, 100, 4);
            assert!(r.save.pacim >= r.save.baseline, "c={c}");
            assert_eq!(r.add_in.pacim, 0);
            assert!(r.add_out.pacim <= r.add_out.baseline, "c={c}");
            let t = r.total();
            assert!(t.pacim < t.baseline, "c={c}: {t:?}");
        }
        // C = 1 is the honest crossover: one counter bit per plane
        // matches the single data channel and the block loses.
        let t = residual_traffic(1, 100, 4).total();
        assert!(t.pacim > t.baseline, "{t:?}");
    }

    #[test]
    fn residual_block_matches_per_edge_formula() {
        // C=16, 9 pixels: save = (16·8 + 8·4)·9, add_in = 0 vs 16·8·9,
        // add_out = (16·4 + 8·4)·9.
        let r = residual_traffic(16, 9, 4);
        assert_eq!(r.save.pacim, 9 * (16 * 8 + 8 * 4));
        assert_eq!(r.save.baseline, 9 * 16 * 8);
        assert_eq!(r.add_in.baseline, 9 * 16 * 8);
        assert_eq!(r.add_out.pacim, 9 * (16 * 4 + 8 * 4));
        assert_eq!(r.total().baseline, 3 * 9 * 16 * 8);
    }

    #[test]
    fn five_bit_mode() {
        // 5-bit approximation (for ImageNet-class accuracy) still saves.
        let t = activation_traffic(512, 5);
        assert!((0.30..0.40).contains(&t.reduction()), "{}", t.reduction());
    }
}
