//! Memory-hierarchy traffic and energy model (§2.1, Fig. 7(b)).
//!
//! PACiM's system-level claim is that replacing LSB activation transfers
//! with sparsity counts cuts cache (and weight DRAM) traffic by 40–50%.
//! [`traffic`] computes the bit traffic of both schemes analytically from
//! layer geometry — the quantities Fig. 7(b) plots — while [`ledger`]
//! records what the executor *measured* as it ran (the sparsity-encoded
//! dataplane's per-edge accounting, carried in `nn::RunStats::traffic`);
//! [`MemoryCounters`] accumulates simulated traffic for energy reports.

pub mod ledger;
pub mod traffic;

pub use ledger::{EdgeKind, LayerTraffic, TrafficLedger};
pub use traffic::{
    activation_traffic, residual_traffic, weight_traffic, ResidualTraffic, TrafficBits,
};

use crate::energy::EnergyModel;

/// Running tally of memory events during a simulation.
#[derive(Debug, Clone, Default)]
pub struct MemoryCounters {
    /// SRAM cache bits read (activations, sparsity words).
    pub sram_read_bits: u64,
    /// SRAM cache bits written.
    pub sram_write_bits: u64,
    /// DRAM bits transferred (weight loading).
    pub dram_bits: u64,
}

impl MemoryCounters {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, other: &MemoryCounters) {
        self.sram_read_bits += other.sram_read_bits;
        self.sram_write_bits += other.sram_write_bits;
        self.dram_bits += other.dram_bits;
    }

    pub fn total_sram_bits(&self) -> u64 {
        self.sram_read_bits + self.sram_write_bits
    }

    /// Energy in pJ under the given model. SRAM is charged per 16-bit
    /// word (§2.1's 30.375 pJ/access figure), DRAM per 64-bit access.
    pub fn energy_pj(&self, m: &EnergyModel) -> f64 {
        self.total_sram_bits() as f64 / 16.0 * m.sram_pj_per_16b
            + self.dram_bits as f64 / 64.0 * m.dram_pj_per_access
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let mut a = MemoryCounters::new();
        a.sram_read_bits = 100;
        let mut b = MemoryCounters::new();
        b.sram_read_bits = 20;
        b.dram_bits = 64;
        a.add(&b);
        assert_eq!(a.sram_read_bits, 120);
        assert_eq!(a.dram_bits, 64);
    }

    #[test]
    fn energy_charges_both_levels() {
        let m = EnergyModel::default();
        let c = MemoryCounters {
            sram_read_bits: 16,
            sram_write_bits: 0,
            dram_bits: 64,
        };
        let e = c.energy_pj(&m);
        assert!((e - (30.375 + 200.0)).abs() < 1e-9);
    }
}
