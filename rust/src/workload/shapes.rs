//! Layer-shape tables for the paper's benchmark networks.
//!
//! Geometry is all the architecture analytics need: DP length (= CiM
//! column depth), output channel count (= MWC demand), and output pixel
//! count (= bit-serial repetitions). Shapes follow the torchvision
//! definitions; CIFAR variants use the standard 3×3-stem ResNet.

use crate::tensor::Conv2dGeom;

/// Input resolution family.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Resolution {
    /// 32×32 (CIFAR-10/100).
    Cifar,
    /// 224×224 (ImageNet).
    ImageNet,
}

/// Kind of a compute layer for CiM mapping purposes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerShapeKind {
    Conv,
    Linear,
}

/// One compute layer's geometry.
#[derive(Debug, Clone)]
pub struct LayerShape {
    pub name: String,
    pub kind: LayerShapeKind,
    /// Convolution geometry; LINEAR layers are encoded as 1×1 convs over
    /// a 1×1 image (dp_len = in_features, out_pixels = 1).
    pub geom: Conv2dGeom,
}

impl LayerShape {
    pub fn conv(
        name: &str,
        in_c: usize,
        out_c: usize,
        hw: usize,
        k: usize,
        stride: usize,
    ) -> Self {
        Self {
            name: name.into(),
            kind: LayerShapeKind::Conv,
            geom: Conv2dGeom {
                in_c,
                in_h: hw,
                in_w: hw,
                out_c,
                kh: k,
                kw: k,
                stride,
                pad: k / 2,
            },
        }
    }

    pub fn linear(name: &str, in_f: usize, out_f: usize) -> Self {
        Self {
            name: name.into(),
            kind: LayerShapeKind::Linear,
            geom: Conv2dGeom {
                in_c: in_f,
                in_h: 1,
                in_w: 1,
                out_c: out_f,
                kh: 1,
                kw: 1,
                stride: 1,
                pad: 0,
            },
        }
    }

    /// DP length = im2col depth = CiM column occupancy.
    pub fn dp_len(&self) -> usize {
        self.geom.dp_len()
    }

    pub fn macs(&self) -> u64 {
        self.geom.macs()
    }

    pub fn out_pixels(&self) -> usize {
        self.geom.out_pixels()
    }
}

fn basic_block(
    v: &mut Vec<LayerShape>,
    tag: &str,
    c_in: usize,
    c_out: usize,
    hw: usize,
    stride: usize,
) {
    v.push(LayerShape::conv(
        &format!("{tag}.conv1"),
        c_in,
        c_out,
        hw,
        3,
        stride,
    ));
    let hw2 = hw / stride;
    v.push(LayerShape::conv(&format!("{tag}.conv2"), c_out, c_out, hw2, 3, 1));
    if stride != 1 || c_in != c_out {
        v.push(LayerShape::conv(
            &format!("{tag}.downsample"),
            c_in,
            c_out,
            hw,
            1,
            stride,
        ));
    }
}

fn bottleneck(
    v: &mut Vec<LayerShape>,
    tag: &str,
    c_in: usize,
    width: usize,
    hw: usize,
    stride: usize,
) {
    let c_out = width * 4;
    v.push(LayerShape::conv(&format!("{tag}.conv1"), c_in, width, hw, 1, 1));
    v.push(LayerShape::conv(
        &format!("{tag}.conv2"),
        width,
        width,
        hw,
        3,
        stride,
    ));
    let hw2 = hw / stride;
    v.push(LayerShape::conv(&format!("{tag}.conv3"), width, c_out, hw2, 1, 1));
    if stride != 1 || c_in != c_out {
        v.push(LayerShape::conv(
            &format!("{tag}.downsample"),
            c_in,
            c_out,
            hw,
            1,
            stride,
        ));
    }
}

/// ResNet-18 layer shapes.
pub fn resnet18(res: Resolution, num_classes: usize) -> Vec<LayerShape> {
    let mut v = Vec::new();
    let hw0 = match res {
        Resolution::Cifar => {
            v.push(LayerShape::conv("stem", 3, 64, 32, 3, 1));
            32
        }
        Resolution::ImageNet => {
            // 7×7/2 stem then 3×3/2 maxpool → 56×56.
            v.push(LayerShape::conv("stem", 3, 64, 224, 7, 2));
            56
        }
    };
    let plan = [(64usize, 64usize, 1usize), (64, 128, 2), (128, 256, 2), (256, 512, 2)];
    let mut hw = hw0;
    for (i, &(c_in, c_out, stride)) in plan.iter().enumerate() {
        basic_block(&mut v, &format!("layer{}.0", i + 1), c_in, c_out, hw, stride);
        hw /= stride;
        basic_block(&mut v, &format!("layer{}.1", i + 1), c_out, c_out, hw, 1);
    }
    v.push(LayerShape::linear("fc", 512, num_classes));
    v
}

/// ResNet-50 layer shapes.
pub fn resnet50(res: Resolution, num_classes: usize) -> Vec<LayerShape> {
    let mut v = Vec::new();
    let hw0 = match res {
        Resolution::Cifar => {
            v.push(LayerShape::conv("stem", 3, 64, 32, 3, 1));
            32
        }
        Resolution::ImageNet => {
            v.push(LayerShape::conv("stem", 3, 64, 224, 7, 2));
            56
        }
    };
    let blocks = [(64usize, 3usize, 1usize), (128, 4, 2), (256, 6, 2), (512, 3, 2)];
    let mut hw = hw0;
    let mut c_in = 64;
    for (i, &(width, reps, stride)) in blocks.iter().enumerate() {
        for r in 0..reps {
            let s = if r == 0 { stride } else { 1 };
            bottleneck(&mut v, &format!("layer{}.{r}", i + 1), c_in, width, hw, s);
            if r == 0 {
                hw /= stride;
            }
            c_in = width * 4;
        }
    }
    v.push(LayerShape::linear("fc", 2048, num_classes));
    v
}

/// VGG16-BN layer shapes.
pub fn vgg16_bn(res: Resolution, num_classes: usize) -> Vec<LayerShape> {
    let cfg: [&[usize]; 5] = [
        &[64, 64],
        &[128, 128],
        &[256, 256, 256],
        &[512, 512, 512],
        &[512, 512, 512],
    ];
    let mut v = Vec::new();
    let mut hw = match res {
        Resolution::Cifar => 32,
        Resolution::ImageNet => 224,
    };
    let mut c_in = 3;
    for (si, stage) in cfg.iter().enumerate() {
        for (ci, &c_out) in stage.iter().enumerate() {
            v.push(LayerShape::conv(
                &format!("features.{si}.{ci}"),
                c_in,
                c_out,
                hw,
                3,
                1,
            ));
            c_in = c_out;
        }
        hw /= 2; // maxpool
    }
    match res {
        Resolution::ImageNet => {
            v.push(LayerShape::linear("classifier.0", 512 * 7 * 7, 4096));
            v.push(LayerShape::linear("classifier.3", 4096, 4096));
            v.push(LayerShape::linear("classifier.6", 4096, num_classes));
        }
        Resolution::Cifar => {
            v.push(LayerShape::linear("classifier.0", 512, 512));
            v.push(LayerShape::linear("classifier.3", 512, 512));
            v.push(LayerShape::linear("classifier.6", 512, num_classes));
        }
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet18_imagenet_macs() {
        // torchvision ResNet-18 ≈ 1.81 GMACs at 224×224 (conv+fc).
        let total: u64 = resnet18(Resolution::ImageNet, 1000)
            .iter()
            .map(|l| l.macs())
            .sum();
        let gmacs = total as f64 / 1e9;
        assert!((1.6..2.1).contains(&gmacs), "got {gmacs} GMACs");
    }

    #[test]
    fn resnet50_imagenet_macs() {
        // ≈ 4.1 GMACs.
        let total: u64 = resnet50(Resolution::ImageNet, 1000)
            .iter()
            .map(|l| l.macs())
            .sum();
        let gmacs = total as f64 / 1e9;
        assert!((3.6..4.6).contains(&gmacs), "got {gmacs} GMACs");
    }

    #[test]
    fn vgg16_imagenet_macs() {
        // ≈ 15.5 GMACs.
        let total: u64 = vgg16_bn(Resolution::ImageNet, 1000)
            .iter()
            .map(|l| l.macs())
            .sum();
        let gmacs = total as f64 / 1e9;
        assert!((14.0..17.0).contains(&gmacs), "got {gmacs} GMACs");
    }

    #[test]
    fn dp_lengths_in_paper_range() {
        // §3.2: CONV DP lengths range 3·3·64..3·3·512; LINEAR 512..4096.
        let shapes = resnet18(Resolution::Cifar, 10);
        let convs: Vec<usize> = shapes
            .iter()
            .filter(|l| l.kind == LayerShapeKind::Conv && l.geom.kh == 3 && l.name != "stem")
            .map(|l| l.dp_len())
            .collect();
        assert!(convs.iter().all(|&d| (3 * 3 * 64..=3 * 3 * 512).contains(&d)));
        let fc = shapes.last().unwrap();
        assert_eq!(fc.dp_len(), 512);
    }

    #[test]
    fn stem_resolution_dependent() {
        let c = resnet18(Resolution::Cifar, 10);
        assert_eq!(c[0].geom.kh, 3);
        let i = resnet18(Resolution::ImageNet, 1000);
        assert_eq!(i[0].geom.kh, 7);
        assert_eq!(i[0].geom.out_h(), 112);
    }

    #[test]
    fn linear_encoding_as_conv() {
        let l = LayerShape::linear("fc", 512, 10);
        assert_eq!(l.dp_len(), 512);
        assert_eq!(l.out_pixels(), 1);
        assert_eq!(l.macs(), 5120);
    }
}
