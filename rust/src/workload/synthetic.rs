//! Artifact-free serving workload: a deterministic random `tiny_resnet`
//! plus a matching random dataset.
//!
//! The serving pipeline must run on a bare container (CI, fresh
//! checkouts) where `artifacts/` has never been compiled. This module
//! generates the same *shape* of workload the L2 build path would
//! produce — a quantized tiny-resnet and a u8 image set agreeing on
//! input quantization — from nothing but a seed, so `pacim serve` and
//! `examples/loadgen.rs` always have real requests to answer. Weights
//! are random (accuracy is meaningless); throughput, latency, batching,
//! and the modeled cycles/energy are exactly as real as with trained
//! artifacts, because the compute is identical.

use super::dataset::Dataset;
use crate::nn::layers::{
    synthetic::{random_store, random_vgg_store},
    tiny_resnet, tiny_vgg, Model,
};
use crate::tensor::QuantParams;
use crate::util::rng::Rng;
use crate::Result;

/// Input quantization shared by [`random_store`]'s `input.oq` entry and
/// the datasets generated here (scale 1/64, zero point 128).
fn input_params() -> QuantParams {
    QuantParams::new(1.0 / 64.0, 128)
}

/// A deterministic random dataset of `n` 3×`hw`×`hw` u8 images with
/// labels in `[0, n_classes)`.
pub fn synthetic_dataset(seed: u64, n: usize, hw: usize, n_classes: usize) -> Dataset {
    let mut rng = Rng::new(seed);
    let images: Vec<u8> = (0..n * 3 * hw * hw).map(|_| rng.below(256) as u8).collect();
    let labels: Vec<u8> = (0..n).map(|_| rng.below(n_classes as u32) as u8).collect();
    Dataset {
        n,
        c: 3,
        h: hw,
        w: hw,
        n_classes,
        params: input_params(),
        images,
        labels,
    }
}

/// The synthetic serving pair: a `tiny_resnet` of width `width` and a
/// dataset of `n_images`, agreeing on input quantization. Deterministic
/// in `seed`.
pub fn synthetic_serving_workload(
    seed: u64,
    width: usize,
    hw: usize,
    n_classes: usize,
    n_images: usize,
) -> Result<(Model, Dataset)> {
    let mut rng = Rng::new(seed);
    let store = random_store(&mut rng, width, n_classes);
    let model = tiny_resnet(&store, hw, n_classes)?;
    let ds = synthetic_dataset(seed ^ 0xDA7A_5E7, n_images, hw, n_classes);
    Ok((model, ds))
}

/// The `tiny_vgg` twin of [`synthetic_serving_workload`]: a random VGG
/// of base width `width` plus a matching dataset. Deterministic in
/// `seed`; the dataset stream is offset so two tenants seeded alike
/// still serve distinct images.
pub fn synthetic_vgg_workload(
    seed: u64,
    width: usize,
    hw: usize,
    n_classes: usize,
    n_images: usize,
) -> Result<(Model, Dataset)> {
    let mut rng = Rng::new(seed);
    let store = random_vgg_store(&mut rng, width, n_classes);
    let model = tiny_vgg(&store, hw, n_classes)?;
    let ds = synthetic_dataset(seed ^ 0x0066_0066, n_images, hw, n_classes);
    Ok((model, ds))
}

/// Resolve a tenant id to its synthetic (model, dataset) pair — the
/// multi-model serving entry (`pacim serve --models`, loadgen `--mix`)
/// shares this table so every surface accepts the same names.
///
/// Accepted ids: `resnet18` / `tinyresnet` → [`synthetic_serving_workload`],
/// `tinyvgg` / `vgg` → [`synthetic_vgg_workload`]. Matching is
/// case-insensitive.
pub fn synthetic_tenant_workload(
    id: &str,
    seed: u64,
    width: usize,
    hw: usize,
    n_classes: usize,
    n_images: usize,
) -> Result<(Model, Dataset)> {
    match id.to_ascii_lowercase().as_str() {
        "resnet18" | "tinyresnet" | "tiny_resnet" => {
            synthetic_serving_workload(seed, width, hw, n_classes, n_images)
        }
        "tinyvgg" | "vgg" | "tiny_vgg" => {
            synthetic_vgg_workload(seed, width, hw, n_classes, n_images)
        }
        other => Err(crate::Error::Config(format!(
            "unknown tenant model '{other}' (expected resnet18|tinyresnet|tinyvgg|vgg)"
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workload_is_deterministic_and_consistent() {
        let (m1, d1) = synthetic_serving_workload(42, 8, 16, 10, 4).unwrap();
        let (m2, d2) = synthetic_serving_workload(42, 8, 16, 10, 4).unwrap();
        assert_eq!(m1.name, m2.name);
        assert_eq!(d1.images, d2.images);
        assert_eq!(d1.labels, d2.labels);
        // Model and dataset must agree on input quantization, so clients
        // can dequantize dataset images into server inputs losslessly.
        assert_eq!(m1.input_params, d1.params);
        assert_eq!(m1.in_hw, d1.h);
        assert_eq!(m1.num_classes, d1.n_classes);
    }

    #[test]
    fn dequantize_quantize_roundtrips_exactly() {
        // The serving executor re-quantizes client floats; with the
        // power-of-two scale this must be lossless for dataset pixels.
        let ds = synthetic_dataset(7, 2, 8, 10);
        for &q in ds.images.iter().take(256) {
            assert_eq!(ds.params.quantize(ds.params.dequantize(q)), q);
        }
    }

    #[test]
    fn different_seeds_differ() {
        let d1 = synthetic_dataset(1, 2, 8, 10);
        let d2 = synthetic_dataset(2, 2, 8, 10);
        assert_ne!(d1.images, d2.images);
    }

    #[test]
    fn vgg_workload_is_consistent_and_distinct() {
        let (m, d) = synthetic_vgg_workload(42, 8, 16, 10, 4).unwrap();
        assert_eq!(m.input_params, d.params);
        assert_eq!(m.in_hw, d.h);
        assert_eq!(m.num_classes, d.n_classes);
        assert!(m.name.starts_with("tiny_vgg"));
        // Same seed, different topology ⇒ a *different* image stream, so
        // co-seeded tenants never serve identical traffic.
        let (_, dr) = synthetic_serving_workload(42, 8, 16, 10, 4).unwrap();
        assert_ne!(d.images, dr.images);
    }

    #[test]
    fn tenant_resolver_accepts_aliases_and_rejects_unknown() {
        for id in ["resnet18", "TinyResNet", "tiny_resnet"] {
            let (m, _) = synthetic_tenant_workload(id, 7, 8, 16, 10, 2).unwrap();
            assert!(m.name.starts_with("tiny_resnet"), "{id} -> {}", m.name);
        }
        for id in ["tinyvgg", "VGG", "tiny_vgg"] {
            let (m, _) = synthetic_tenant_workload(id, 7, 8, 16, 10, 2).unwrap();
            assert!(m.name.starts_with("tiny_vgg"), "{id} -> {}", m.name);
        }
        let err = synthetic_tenant_workload("alexnet", 7, 8, 16, 10, 2).unwrap_err();
        assert!(err.to_string().contains("alexnet"), "{err}");
    }
}
