//! Synthetic dataset container (`artifacts/dataset.bin`).
//!
//! The dataset is generated deterministically by
//! `python/compile/datagen.py` at build time (our substitution for
//! CIFAR/ImageNet — DESIGN.md §3) and consumed here by the accuracy
//! benches and the serving example. Binary format, little-endian:
//!
//! ```text
//! magic   b"PACD"
//! version u32 = 1
//! n, c, h, w, n_classes : u32
//! scale   f32   // input quantization params (uint8 affine)
//! zero_pt i32
//! images  n·c·h·w bytes (quantized u8, NCHW)
//! labels  n bytes
//! ```

use crate::tensor::QuantParams;
use crate::{Error, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"PACD";
const VERSION: u32 = 1;

/// An in-memory quantized image-classification dataset.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub n: usize,
    pub c: usize,
    pub h: usize,
    pub w: usize,
    pub n_classes: usize,
    pub params: QuantParams,
    /// NCHW, quantized.
    pub images: Vec<u8>,
    pub labels: Vec<u8>,
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_f32(r: &mut impl Read) -> Result<f32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(f32::from_le_bytes(b))
}

impl Dataset {
    pub fn image(&self, i: usize) -> &[u8] {
        let sz = self.c * self.h * self.w;
        &self.images[i * sz..(i + 1) * sz]
    }

    pub fn label(&self, i: usize) -> usize {
        self.labels[i] as usize
    }

    pub fn image_elems(&self) -> usize {
        self.c * self.h * self.w
    }

    /// Load from `dataset.bin`.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let mut f = std::fs::File::open(path.as_ref()).map_err(|e| {
            Error::Artifact(format!(
                "cannot open dataset {} (run `make artifacts`): {e}",
                path.as_ref().display()
            ))
        })?;
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            return Err(Error::Artifact("bad dataset magic".into()));
        }
        let version = read_u32(&mut f)?;
        if version != VERSION {
            return Err(Error::Artifact(format!("unsupported dataset version {version}")));
        }
        let n = read_u32(&mut f)? as usize;
        let c = read_u32(&mut f)? as usize;
        let h = read_u32(&mut f)? as usize;
        let w = read_u32(&mut f)? as usize;
        let n_classes = read_u32(&mut f)? as usize;
        let scale = read_f32(&mut f)?;
        let zp = read_u32(&mut f)? as i32;
        let mut images = vec![0u8; n * c * h * w];
        f.read_exact(&mut images)?;
        let mut labels = vec![0u8; n];
        f.read_exact(&mut labels)?;
        // Reject trailing garbage — catches format drift early.
        let mut probe = [0u8; 1];
        if f.read(&mut probe)? != 0 {
            return Err(Error::Artifact("trailing bytes in dataset.bin".into()));
        }
        for &l in &labels {
            if l as usize >= n_classes {
                return Err(Error::Artifact(format!(
                    "label {l} out of range ({n_classes} classes)"
                )));
            }
        }
        Ok(Self {
            n,
            c,
            h,
            w,
            n_classes,
            params: QuantParams::new(scale, zp),
            images,
            labels,
        })
    }

    /// Write in the same format (used by tests and tooling).
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut f = std::fs::File::create(path)?;
        f.write_all(MAGIC)?;
        for v in [
            VERSION,
            self.n as u32,
            self.c as u32,
            self.h as u32,
            self.w as u32,
            self.n_classes as u32,
        ] {
            f.write_all(&v.to_le_bytes())?;
        }
        f.write_all(&self.params.scale.to_le_bytes())?;
        f.write_all(&(self.params.zero_point as u32).to_le_bytes())?;
        f.write_all(&self.images)?;
        f.write_all(&self.labels)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        Dataset {
            n: 3,
            c: 1,
            h: 2,
            w: 2,
            n_classes: 2,
            params: QuantParams::new(0.05, 3),
            images: (0..12).collect(),
            labels: vec![0, 1, 1],
        }
    }

    #[test]
    fn roundtrip() {
        let d = toy();
        let path = std::env::temp_dir().join("pacim_test_dataset.bin");
        d.save(&path).unwrap();
        let back = Dataset::load(&path).unwrap();
        assert_eq!(back.n, 3);
        assert_eq!(back.images, d.images);
        assert_eq!(back.labels, d.labels);
        assert_eq!(back.params, d.params);
        assert_eq!(back.image(1), &[4, 5, 6, 7]);
        assert_eq!(back.label(2), 1);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let path = std::env::temp_dir().join("pacim_test_badmagic.bin");
        std::fs::write(&path, b"NOPE0000000000000000000000000000").unwrap();
        assert!(Dataset::load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_truncated() {
        let d = toy();
        let path = std::env::temp_dir().join("pacim_test_trunc.bin");
        d.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 2]).unwrap();
        assert!(Dataset::load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_out_of_range_labels() {
        let mut d = toy();
        d.labels = vec![0, 1, 5];
        let path = std::env::temp_dir().join("pacim_test_badlabel.bin");
        d.save(&path).unwrap();
        assert!(Dataset::load(&path).is_err());
        std::fs::remove_file(path).ok();
    }
}
