//! Workload definitions: the layer-shape tables of the paper's benchmark
//! networks and the synthetic dataset loader.
//!
//! The cycle / energy / traffic experiments (Fig. 7, Tables 3–4) depend
//! only on layer *geometry*, which we take verbatim from ResNet-18/50 and
//! VGG16-BN at CIFAR (32×32) and ImageNet (224×224) resolutions. Accuracy
//! experiments run the actually-trained tiny models on the synthetic
//! dataset (see DESIGN.md §3 substitutions).

pub mod dataset;
pub mod shapes;
pub mod synthetic;

pub use dataset::Dataset;
pub use shapes::{resnet18, resnet50, vgg16_bn, LayerShape, LayerShapeKind, Resolution};
pub use synthetic::{
    synthetic_dataset, synthetic_serving_workload, synthetic_tenant_workload,
    synthetic_vgg_workload,
};
