//! `pacim` — CLI for the PACiM architecture simulator and serving runtime.
//!
//! Subcommands (no clap in the offline vendor set; args are parsed by
//! hand):
//!
//! ```text
//! pacim info                     # artifact + configuration summary
//! pacim map [--bits N]           # print the digital/sparsity computing map
//! pacim rmse [--dp N] [--iters N]  # PAC Monte-Carlo error analysis
//! pacim simulate [--model resnet18|resnet50|vgg16] [--res cifar|imagenet]
//!                                # schedule a workload, print cycles/energy/traffic
//! pacim accuracy [--images N] [--dynamic]  # exact vs PAC accuracy on artifacts
//! pacim serve [--requests N] [--clients N] [--workers N] [--batch N]
//!             [--batch-wait-ms T] [--queue-cap N] [--dynamic] [--exact]
//!             [--models a,b] [--pjrt]
//!                                # serve via the PAC-native executor pool
//!                                # (artifacts when built, synthetic
//!                                # workload otherwise; --models hosts
//!                                # >= 2 synthetic tenants behind one
//!                                # routing front door; --pjrt needs the
//!                                # `pjrt` feature + artifacts)
//! pacim tune [--quick] [--images N] [--lambda X] [--out PATH]
//!            [--model resnet18|resnet50|vgg16] [--res cifar|imagenet]
//!                                # design-space autotune: sweep threshold
//!                                # maps x banks x tile rows x traffic
//!                                # price λ, print + emit the Pareto
//!                                # front as BENCH_tune.json
//! pacim faultsweep [--quick] [--images N] [--seed S] [--sigma X] [--out PATH]
//!                                # seeded fault injection: accuracy vs
//!                                # BER with and without confidence-gated
//!                                # PAC→exact escalation, emitted as
//!                                # BENCH_resilience.json
//! ```

use pacim::coordinator::{schedule_model, ScheduleConfig};
use pacim::energy::EnergyModel;
use pacim::engine::EngineBuilder;
use pacim::nn::{tiny_resnet, PacConfig, WeightStore};
use pacim::pac::error_analysis::{pac_rmse, BitModel};
use pacim::pac::ComputeMap;
use pacim::runtime::manifest::artifacts_dir;
use pacim::runtime::Manifest;
use pacim::workload::{resnet18, resnet50, vgg16_bn, Dataset, Resolution};

fn arg_value(args: &[String], flag: &str) -> Option<String> {
    args.iter()
        .position(|a| a == flag)
        .and_then(|i| args.get(i + 1).cloned())
}

fn has_flag(args: &[String], flag: &str) -> bool {
    args.iter().any(|a| a == flag)
}

/// Every subcommand with its one-line description — the single source
/// the usage text renders, so an unknown subcommand always shows the
/// full menu (pinned by `tests/cli_usage.rs`).
const SUBCOMMANDS: &[(&str, &str)] = &[
    ("info", "artifact + configuration summary"),
    ("map", "print the digital/sparsity computing map"),
    ("rmse", "PAC Monte-Carlo error analysis"),
    ("simulate", "schedule a workload; print cycles/energy/traffic"),
    ("accuracy", "exact vs PAC accuracy on the built artifacts"),
    ("serve", "serve inference via the PAC-native executor pool"),
    ("tune", "design-space autotune: Pareto front over thresholds x banks x tiles x lambda"),
    ("faultsweep", "fault-injection resilience: accuracy vs BER with/without escalation"),
];

fn usage() {
    let mut s = String::from("usage: pacim <subcommand> [options]\n\nsubcommands:\n");
    for (name, desc) in SUBCOMMANDS {
        s.push_str(&format!("  pacim {name:<9} {desc}\n"));
    }
    s.push_str("\nsee rust/src/main.rs header for per-subcommand options");
    eprintln!("{s}");
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "info" => info(),
        "map" => map(&args),
        "rmse" => rmse(&args),
        "simulate" => simulate(&args),
        "accuracy" => accuracy(&args),
        "serve" => serve(&args),
        "tune" => tune(&args),
        "faultsweep" => faultsweep(&args),
        _ => {
            usage();
            Ok(())
        }
    }
}

fn info() -> anyhow::Result<()> {
    println!("PACiM reproduction — ICCAD 2024 (Zhang et al.)");
    let m = EnergyModel::default();
    println!("energy model (65nm @0.6V calibration):");
    println!("  D-CiM      : {:8.2} TOPS/W (1b/1b)", m.dcim_tops_w());
    println!("  PCU + Acc  : {:8.2} TOPS/W (1b/1b)", m.pcu_tops_w());
    println!(
        "  PACiM peak : {:8.2} TOPS/W (1b/1b) = {:.2} TOPS/W (8b/8b)",
        m.pacim_peak().tops_w_1b,
        m.pacim_peak().tops_w_8b
    );
    match Manifest::load(artifacts_dir()) {
        Ok(man) => {
            println!("artifacts ({}):", man.dir.display());
            println!("  model   : {}", man.get("model")?);
            println!("  batch   : {}", man.batch()?);
            println!("  classes : {}", man.classes()?);
        }
        Err(e) => println!("artifacts: not built ({e})"),
    }
    Ok(())
}

fn map(args: &[String]) -> anyhow::Result<()> {
    let bits: u32 = arg_value(args, "--bits")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(4);
    let m = ComputeMap::operand_based(bits, bits);
    println!("computing map ({}):", m.name);
    print!("{}", m.render());
    println!(
        "digital cycles: {} / 64  ({}% reduction)",
        m.digital_cycles(),
        100 * (64 - m.digital_cycles()) / 64
    );
    Ok(())
}

fn rmse(args: &[String]) -> anyhow::Result<()> {
    let dp: usize = arg_value(args, "--dp")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(1024);
    let iters: u64 = arg_value(args, "--iters")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(100_000);
    println!("PAC Monte-Carlo RMSE, DP length {dp}, {iters} iterations:");
    for (sw, sx) in [(0.25, 0.1), (0.5, 0.3), (0.7, 0.3)] {
        let r = pac_rmse(dp, sw, sx, iters, 2024, BitModel::Iid);
        println!(
            "  Sw={sw:4} Sx={sx:4}  ->  RMSE {:6.2} LSB = {:5.3}% (bias {:+.3})",
            r.rmse_lsb, r.rmse_pct, r.bias_lsb
        );
    }
    Ok(())
}

fn simulate(args: &[String]) -> anyhow::Result<()> {
    let model = arg_value(args, "--model").unwrap_or_else(|| "resnet18".into());
    let res = match arg_value(args, "--res").as_deref() {
        Some("imagenet") => Resolution::ImageNet,
        _ => Resolution::Cifar,
    };
    let classes = if res == Resolution::ImageNet { 1000 } else { 10 };
    let shapes = match model.as_str() {
        "resnet18" => resnet18(res, classes),
        "resnet50" => resnet50(res, classes),
        "vgg16" => vgg16_bn(res, classes),
        other => anyhow::bail!("unknown model '{other}'"),
    };
    let em = EnergyModel::default();
    println!("workload {model} ({res:?}): {} compute layers", shapes.len());
    for (label, cfg) in [
        ("digital 8b/8b", ScheduleConfig::digital_baseline()),
        ("PACiM static 4b", ScheduleConfig::pacim_default()),
        ("PACiM dynamic", ScheduleConfig::pacim_dynamic()),
    ] {
        let rep = schedule_model(&shapes, &cfg);
        let e_comp = rep.compute_energy_pj(&em) / 1e6;
        let e_mem = rep.memory_energy_pj(&em, cfg.msb_bits < 8) / 1e6;
        println!(
            "  {label:16} cycles {:>13}  E_compute {:9.2} uJ  E_mem {:9.2} uJ  act-traffic red. {:5.1}%",
            rep.total_macs_cycles(),
            e_comp,
            e_mem,
            rep.act_traffic_reduction() * 100.0
        );
    }
    Ok(())
}

fn accuracy(args: &[String]) -> anyhow::Result<()> {
    let man = Manifest::load(artifacts_dir())?;
    let store = WeightStore::load(man.path("weights")?)?;
    let ds = Dataset::load(man.path("dataset")?)?;
    let model = tiny_resnet(&store, ds.h, ds.n_classes)?;
    let n: usize = arg_value(args, "--images")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(200)
        .min(ds.n);
    let images: Vec<&[u8]> = (0..n).map(|i| ds.image(i)).collect();
    let labels: Vec<usize> = (0..n).map(|i| ds.label(i)).collect();
    let threads = std::thread::available_parallelism()?.get();

    let exact = EngineBuilder::new(model.clone()).exact().build()?;
    let ev_e = exact.evaluate(&images, &labels, threads)?;
    println!(
        "exact 8b/8b accuracy : {:.2}% ({n} images)",
        ev_e.accuracy * 100.0
    );

    let mut builder = EngineBuilder::new(model).pac(PacConfig::default());
    if has_flag(args, "--dynamic") {
        builder = builder.dynamic(pacim::arch::ThresholdSet::default_cifar());
    }
    let pac = builder.build()?;
    let ev_p = pac.evaluate(&images, &labels, threads)?;
    println!(
        "PAC 4-bit accuracy   : {:.2}%  (loss {:+.2}%)",
        ev_p.accuracy * 100.0,
        (ev_p.accuracy - ev_e.accuracy) * 100.0
    );
    let t = &ev_p.stats.traffic;
    println!(
        "measured act traffic : {:.1}% reduction vs 8-bit dense \
         ({} of {} edges sparsity-encoded)",
        t.reduction() * 100.0,
        t.encoded_layer_count(),
        t.layers().len()
    );
    for (name, e) in pac.traffic_rows(t) {
        println!(
            "  {name:<16} {:<13} {:>4} ch  {:>10} -> {:>10} bits  {}{:6.1}%",
            e.kind.as_str(),
            e.group_elems,
            e.baseline_bits,
            e.bits,
            if e.encoded { "encoded " } else { "dense   " },
            e.reduction() * 100.0
        );
    }
    if ev_p.stats.levels.total() > 0 {
        println!(
            "dynamic avg cycles   : {:.2} (reduction vs 64: {:.1}%)",
            ev_p.stats.levels.average_cycles(),
            ev_p.stats.levels.cycle_reduction_vs_digital() * 100.0
        );
    }
    Ok(())
}

/// `pacim tune` — joint design-space autotune (see `pacim::arch::dse`).
///
/// Accuracy and the average digital cycle count are *measured* on a
/// validation split (built artifacts when present, the synthetic
/// serving workload otherwise — one engine evaluation per distinct
/// threshold map); cycles and bits are *modeled* by pricing the chosen
/// paper workload's multibank schedule at every grid point. Prints the
/// non-dominated Pareto front plus the λ-vs-cycles-only schedule
/// comparison, and emits the schema-gated `BENCH_tune.json`
/// (`pacim::util::benchfmt::TuneReport`).
fn tune(args: &[String]) -> anyhow::Result<()> {
    use pacim::arch::dse::{sweep, DseAxes, DseConfig};
    use pacim::util::benchfmt::{validate_tune, TunePointBench, TuneReport, TuneScheduleBench};

    let quick = has_flag(args, "--quick")
        || std::env::var("PACIM_BENCH_QUICK")
            .ok()
            .is_some_and(|v| v != "0" && !v.is_empty());
    let n_images: usize = arg_value(args, "--images")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(if quick { 48 } else { 200 });
    let lambda: Option<f64> = arg_value(args, "--lambda").map(|s| s.parse()).transpose()?;
    let out_path = arg_value(args, "--out").unwrap_or_else(|| "BENCH_tune.json".into());
    let wl_name = arg_value(args, "--model").unwrap_or_else(|| "resnet18".into());
    let res = match arg_value(args, "--res").as_deref() {
        Some("imagenet") => Resolution::ImageNet,
        _ => Resolution::Cifar,
    };
    let classes = if res == Resolution::ImageNet { 1000 } else { 10 };
    let workload = match wl_name.as_str() {
        "resnet18" => resnet18(res, classes),
        "resnet50" => resnet50(res, classes),
        "vgg16" => vgg16_bn(res, classes),
        other => anyhow::bail!("unknown model '{other}'"),
    };
    let workload_label = format!(
        "{wl_name}-{}",
        if res == Resolution::ImageNet { "imagenet" } else { "cifar" }
    );

    let (model, ds, source) = serving_workload();
    let n = n_images.min(ds.n).max(1);
    let images: Vec<&[u8]> = (0..n).map(|i| ds.image(i)).collect();
    let labels: Vec<usize> = (0..n).map(|i| ds.label(i)).collect();
    let threads = std::thread::available_parallelism()?.get();

    let mut axes = if quick { DseAxes::quick() } else { DseAxes::full() };
    if let Some(l) = lambda {
        anyhow::ensure!(l > 0.0, "--lambda must be positive");
        axes.lambdas = vec![0.0, l * 0.25, l];
    }
    println!(
        "tune: {} grid points ({} engine evals x {n} images) | workload {workload_label} | \
         eval model {} ({source})",
        axes.points(),
        axes.thresholds.len(),
        model.name
    );
    let cfg = DseConfig { axes, workload, workload_label: workload_label.clone(), threads };
    let out = sweep(&model, &images, &labels, &cfg)?;

    println!("Pareto front: {} of {} points non-dominated", out.front.len(), out.points.len());
    println!(
        "  {:<24} {:>5} {:>5} {:>7} {:>7} {:>7} {:>13} {:>13}",
        "thresholds", "banks", "rows", "lambda", "acc%", "avgcyc", "cycles", "bits"
    );
    for &i in &out.front {
        let p = &out.points[i];
        let th = p
            .thresholds
            .map(|t| format!("[{:.3} {:.3} {:.3}]", t.th0, t.th1, t.th2))
            .unwrap_or_else(|| "static".into());
        println!(
            "  {th:<24} {:>5} {:>5} {:>7.3} {:>6.2}% {:>7.2} {:>13} {:>13}",
            p.banks,
            p.rows,
            p.lambda,
            p.accuracy * 100.0,
            p.avg_digital_cycles,
            p.cycles,
            p.bits
        );
    }
    for c in &out.comparisons {
        let bits_delta = 100.0 * (c.bits_priced as f64 / c.bits_cycles_only as f64 - 1.0);
        let cyc_delta = 100.0 * (c.cycles_priced as f64 / c.cycles_cycles_only as f64 - 1.0);
        println!(
            "lambda {:.3} on {} (banks {}, rows {}): bits {} -> {} ({bits_delta:+.1}%), \
             cycles {} -> {} ({cyc_delta:+.1}%), {} layer(s) replayed",
            c.lambda,
            c.workload,
            c.banks,
            c.rows,
            c.bits_cycles_only,
            c.bits_priced,
            c.cycles_cycles_only,
            c.cycles_priced,
            c.replayed_layers
        );
    }
    println!(
        "traffic cross-check: measured {} bits, analytic {} bits",
        out.measured_bits, out.analytic_bits
    );
    println!(
        "residual edges: {} bits fused vs {} dense round-trip",
        out.residual_bits_encoded, out.residual_bits_dense
    );
    if source == "synthetic" {
        println!("note: synthetic weights — accuracy is noise; cycles/bits are real");
    }

    let report = TuneReport {
        bench: "tune".into(),
        quick,
        model: format!("{}-{source}", model.name),
        workload: workload_label,
        images: n,
        points: out
            .points
            .iter()
            .enumerate()
            .map(|(i, p)| TunePointBench {
                banks: p.banks,
                rows: p.rows,
                thresholds: p.thresholds.map(|t| [t.th0, t.th1, t.th2]),
                lambda: p.lambda,
                accuracy: p.accuracy,
                avg_digital_cycles: p.avg_digital_cycles,
                cycles: p.cycles,
                bits: p.bits,
                on_front: out.front.contains(&i),
            })
            .collect(),
        schedules: out
            .comparisons
            .iter()
            .map(|c| TuneScheduleBench {
                workload: c.workload.clone(),
                banks: c.banks,
                rows: c.rows,
                lambda: c.lambda,
                cycles_cycles_only: c.cycles_cycles_only,
                bits_cycles_only: c.bits_cycles_only,
                cycles_priced: c.cycles_priced,
                bits_priced: c.bits_priced,
                replayed_layers: c.replayed_layers,
            })
            .collect(),
        measured_bits: out.measured_bits,
        analytic_bits: out.analytic_bits,
        residual_bits_encoded: out.residual_bits_encoded,
        residual_bits_dense: out.residual_bits_dense,
    };
    let json = serde_json::to_string_pretty(&report)?;
    validate_tune(&json).map_err(|e| anyhow::anyhow!("BENCH_tune self-check failed: {e}"))?;
    std::fs::write(&out_path, &json)?;
    println!("wrote {out_path}");
    Ok(())
}

/// `pacim faultsweep` — seeded fault-injection resilience sweep
/// (DESIGN.md §15).
///
/// Self-labels the split with the exact engine's own argmax (so
/// `acc_exact` is 1.0 by construction and the sweep needs no trained
/// artifacts), calibrates the escalation margin floor at the 85th
/// percentile of clean PAC logit margins, then scores every BER point
/// through the faulted PAC engine with and without `Fidelity::Auto`
/// escalation. Emits the schema-gated `BENCH_resilience.json`
/// (`pacim::util::benchfmt::ResilienceReport`); with
/// `PACIM_ENFORCE_RESILIENCE=1` the run also fails unless fault-off
/// runs were bit-identical and escalation recovered at least half the
/// fault-induced accuracy loss at BER 1e-3.
fn faultsweep(args: &[String]) -> anyhow::Result<()> {
    use pacim::engine::Fidelity;
    use pacim::fault::FaultConfig;
    use pacim::nn::EscalationConfig;
    use pacim::util::benchfmt::{
        enforce_resilience, resilience_recovered, validate_resilience, ResilienceReport,
        ResilienceRow, RESILIENCE_GATE_BER,
    };

    let quick = has_flag(args, "--quick")
        || std::env::var("PACIM_BENCH_QUICK")
            .ok()
            .is_some_and(|v| v != "0" && !v.is_empty());
    let n_images: usize = arg_value(args, "--images")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(if quick { 48 } else { 128 });
    let out_path = arg_value(args, "--out").unwrap_or_else(|| "BENCH_resilience.json".into());
    let seed: u64 = match arg_value(args, "--seed") {
        Some(s) => s.parse()?,
        None => match std::env::var("PACIM_FAULT_SEED") {
            Ok(s) => s.parse()?,
            Err(_) => 2024,
        },
    };
    let sigma: f64 = arg_value(args, "--sigma")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(2.0);

    let (model, ds, source) = serving_workload();
    let n = n_images.min(ds.n).max(1);
    let images: Vec<&[u8]> = (0..n).map(|i| ds.image(i)).collect();
    let threads = std::thread::available_parallelism()?.get();

    // Ground truth = the exact engine's own argmax: acc_exact is 1.0 by
    // construction, so every drop below it is attributable to PAC
    // approximation plus injected faults, never to the weights.
    let exact = EngineBuilder::new(model.clone()).exact().build()?;
    let mut es = exact.session();
    let mut labels = Vec::with_capacity(n);
    for img in &images {
        labels.push(argmax_last(&es.infer(img)?.logits));
    }
    drop(es);
    let acc_exact = exact.evaluate(&images, &labels, threads)?.accuracy;

    // Calibrate the margin floor on the clean PAC engine. Under fault
    // the sweep wants an aggressive monitor, so take the 85th percentile
    // of clean logit margins: a fault that erodes an image's margin into
    // the bottom ~85% of the clean distribution triggers an exact rerun.
    let clean = EngineBuilder::new(model.clone()).pac(PacConfig::serving()).build()?;
    let mut cs = clean.session();
    let mut margins = Vec::with_capacity(n);
    let mut clean_logits = Vec::with_capacity(n);
    for img in &images {
        let inf = cs.infer(img)?;
        margins.push(logit_margin(&inf.logits));
        clean_logits.push(inf.logits);
    }
    drop(cs);
    margins.sort_by(|a, b| a.partial_cmp(b).expect("margins are finite"));
    let min_margin = margins[(margins.len() - 1) * 85 / 100];

    // Fault-off bit-identity: an engine carrying FaultConfig::off() must
    // reproduce the fault-free engine's logits bit for bit.
    let off = EngineBuilder::new(model.clone())
        .pac(PacConfig::serving())
        .fault(FaultConfig::off())
        .build()?;
    let mut os = off.session();
    let mut fault_off_bit_identical = true;
    for (img, want) in images.iter().zip(&clean_logits) {
        if &os.infer(img)?.logits != want {
            fault_off_bit_identical = false;
            break;
        }
    }
    drop(os);

    let bers: &[f64] = if quick {
        &[0.0, RESILIENCE_GATE_BER]
    } else {
        &[0.0, 1e-4, RESILIENCE_GATE_BER, 1e-2]
    };
    println!(
        "faultsweep: {n} images | model {} ({source}) | seed {seed} | margin floor \
         {min_margin:.4} (85th pct of clean margins) | fault-off bit-identical: \
         {fault_off_bit_identical}",
        model.name
    );
    println!(
        "  {:>8} {:>8} {:>8} {:>8} {:>8} {:>9} {:>9} {:>9} {:>9}",
        "ber", "exact%", "plain%", "escal%", "esc-rate", "w-flips", "e-flips", "pcu-ev",
        "recovered"
    );
    let mut rows = Vec::new();
    for &ber in bers {
        let fc = FaultConfig::at_ber(seed, ber);
        let plain = EngineBuilder::new(model.clone())
            .pac(PacConfig::serving())
            .fault(fc)
            .build()?;
        let ev_plain = plain.evaluate(&images, &labels, threads)?;
        let escal = EngineBuilder::new(model.clone())
            .pac(PacConfig::serving())
            .fault(fc)
            .escalation(EscalationConfig { min_margin, sigma })
            .build()?;
        let ev_esc = escal.evaluate_with(&images, &labels, threads, Fidelity::Auto)?;
        let f = &ev_plain.stats.faults;
        let row = ResilienceRow {
            ber,
            acc_exact,
            acc_plain: ev_plain.accuracy,
            acc_escalated: ev_esc.accuracy,
            escalation_rate: ev_esc.stats.escalations as f64 / n as f64,
            weight_bits_flipped: f.total_weight_bits(),
            edge_bits_flipped: f.total_edge_bits(),
            pcu_noise_events: f.total_pcu_events(),
            recovered: resilience_recovered(acc_exact, ev_plain.accuracy, ev_esc.accuracy),
        };
        println!(
            "  {:>8.0e} {:>8.2} {:>8.2} {:>8.2} {:>7.1}% {:>9} {:>9} {:>9} {:>9.3}",
            row.ber,
            row.acc_exact * 100.0,
            row.acc_plain * 100.0,
            row.acc_escalated * 100.0,
            row.escalation_rate * 100.0,
            row.weight_bits_flipped,
            row.edge_bits_flipped,
            row.pcu_noise_events,
            row.recovered
        );
        rows.push(row);
    }
    if source == "synthetic" {
        println!(
            "note: synthetic weights — labels are self-generated by the exact engine, so \
             the sweep measures fidelity to it, not dataset accuracy"
        );
    }

    let report = ResilienceReport {
        bench: "resilience".into(),
        quick,
        model: format!("{}-{source}", model.name),
        images: n,
        min_margin: min_margin as f64,
        fault_off_bit_identical,
        rows,
    };
    let json = serde_json::to_string_pretty(&report)?;
    let checked = validate_resilience(&json)
        .map_err(|e| anyhow::anyhow!("BENCH_resilience self-check failed: {e}"))?;
    if std::env::var("PACIM_ENFORCE_RESILIENCE").is_ok_and(|v| v != "0" && !v.is_empty()) {
        enforce_resilience(&checked)
            .map_err(|e| anyhow::anyhow!("resilience gate failed: {e}"))?;
        println!("resilience gate enforced: fault-off bit-identical, recovery >= 50% at BER 1e-3");
    }
    std::fs::write(&out_path, &json)?;
    println!("wrote {out_path}");
    Ok(())
}

/// Last-wins argmax — the same tie rule `engine::session` scores
/// evaluations with, so self-generated labels always agree with the
/// exact engine's own verdict.
fn argmax_last(v: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in v.iter().enumerate() {
        if x >= v[best] {
            best = i;
        }
    }
    best
}

/// Top-1 minus top-2 logit (the escalation monitor's margin); 0 for
/// degenerate outputs.
fn logit_margin(v: &[f32]) -> f32 {
    if v.len() < 2 {
        return 0.0;
    }
    let (mut top, mut second) = (f32::NEG_INFINITY, f32::NEG_INFINITY);
    for &x in v {
        if x >= top {
            second = top;
            top = x;
        } else if x > second {
            second = x;
        }
    }
    top - second
}

fn serve(args: &[String]) -> anyhow::Result<()> {
    if has_flag(args, "--pjrt") {
        return serve_pjrt(args);
    }
    if let Some(models) = arg_value(args, "--models") {
        return serve_multi(args, &models);
    }
    serve_pac(args)
}

/// Multi-model serving (`pacim serve --models resnet18,tinyvgg`): one
/// tenant pool per id behind `PacExecutor::serve_registry`'s routing
/// front door, driven by closed-loop round-robin clients. The built
/// artifacts hold a single model, so tenants always come from the
/// synthetic workload table
/// ([`pacim::workload::synthetic_tenant_workload`]) — accuracy is
/// noise, but latency, stealing, and traffic attribution are real.
fn serve_multi(args: &[String], models: &str) -> anyhow::Result<()> {
    use pacim::coordinator::{BatchPolicy, ModelRegistry, ModelSpec};
    use pacim::runtime::PacExecutor;
    use std::sync::atomic::{AtomicUsize, Ordering::Relaxed};

    let ids: Vec<String> = models
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    anyhow::ensure!(ids.len() >= 2, "--models needs >= 2 comma-separated ids, got '{models}'");
    let requests: usize = arg_value(args, "--requests")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(128);
    let clients: usize = arg_value(args, "--clients")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(8)
        .max(1);
    let workers: usize = arg_value(args, "--workers")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(2)
        .max(1);
    let batch: usize = arg_value(args, "--batch")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(8)
        .max(1);
    let wait_ms: u64 = arg_value(args, "--batch-wait-ms")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(2);
    let queue_cap: usize = arg_value(args, "--queue-cap")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(1024);

    let policy = BatchPolicy {
        max_wait: std::time::Duration::from_millis(wait_ms),
        workers,
        queue_cap,
        ..BatchPolicy::default()
    };
    let mut registry = ModelRegistry::new();
    let mut datasets = Vec::new();
    for (i, id) in ids.iter().enumerate() {
        let (model, ds) =
            pacim::workload::synthetic_tenant_workload(id, 2024 + i as u64, 8, 16, 10, 64)?;
        let engine = EngineBuilder::new(model)
            .pac(PacConfig::serving())
            .parallelism(pacim::util::Parallelism::off())
            .build()?;
        registry =
            registry.register(ModelSpec::new(id.clone(), engine).batch(batch).policy(policy))?;
        datasets.push(ds);
    }
    let server = PacExecutor::serve_registry(registry)?;
    let h = server.handle();
    println!(
        "serving {} tenants ({}) | {workers} workers/pool | batch {batch} | \
         {clients} clients | {requests} requests round-robin",
        ids.len(),
        ids.join(", ")
    );

    let next = AtomicUsize::new(0);
    let shed = AtomicUsize::new(0);
    let t0 = std::time::Instant::now();
    std::thread::scope(|s| {
        for _ in 0..clients {
            let h = h.clone();
            let (next, shed) = (&next, &shed);
            let (ids, datasets) = (&ids, &datasets);
            s.spawn(move || loop {
                let i = next.fetch_add(1, Relaxed);
                if i >= requests {
                    break;
                }
                let t = i % ids.len();
                let ds = &datasets[t];
                let idx = (i / ids.len()) % ds.n;
                let img: Vec<f32> =
                    ds.image(idx).iter().map(|&q| ds.params.dequantize(q)).collect();
                if let Err(e) = h.infer(&ids[t], img) {
                    shed.fetch_add(1, Relaxed);
                    eprintln!("request {i} ({}): {e}", ids[t]);
                }
            });
        }
    });
    let wall = t0.elapsed();
    let shed = shed.load(Relaxed);
    let all = server.stop();
    let total: u64 = all.iter().map(|(_, m)| m.requests).sum();
    println!(
        "served {total}/{requests} requests in {:.1} ms ({shed} shed/dropped) | \
         aggregate {:.1} img/s",
        wall.as_secs_f64() * 1e3,
        total as f64 / wall.as_secs_f64()
    );
    for (id, m) in &all {
        println!(
            "  {id:<12} {:>5} reqs | p50 {:>7.0} us | p99 {:>7.0} us | mean batch {:.2} | \
             steals {} ({:.1}%) | bits/req {:.0}",
            m.requests,
            m.latency_percentile_us(50.0),
            m.latency_percentile_us(99.0),
            m.mean_batch_occupancy(),
            m.steals,
            m.steal_rate() * 100.0,
            m.bits_per_request()
        );
        for sh in &m.per_shard {
            println!(
                "    shard {}: {} submitted, {} stolen, max depth {}",
                sh.shard, sh.submitted, sh.stolen, sh.max_depth
            );
        }
    }
    println!("note: synthetic tenants — accuracy is noise; latency/steals/traffic are real");
    Ok(())
}

/// Load the trained artifact model + dataset, or fall back to the
/// deterministic synthetic serving workload when `artifacts/` has not
/// been built (bare containers, CI).
fn serving_workload() -> (pacim::nn::Model, Dataset, &'static str) {
    let load = || -> anyhow::Result<(pacim::nn::Model, Dataset)> {
        let man = Manifest::load(artifacts_dir())?;
        let ds = Dataset::load(man.path("dataset")?)?;
        let store = WeightStore::load(man.path("weights")?)?;
        let model = tiny_resnet(&store, ds.h, ds.n_classes)?;
        Ok((model, ds))
    };
    match load() {
        Ok((model, ds)) => (model, ds, "artifacts"),
        Err(e) => {
            eprintln!("artifacts unavailable ({e}); serving the synthetic workload");
            let (model, ds) = pacim::workload::synthetic_serving_workload(2024, 8, 16, 10, 256)
                .expect("synthetic workload construction is infallible");
            (model, ds, "synthetic")
        }
    }
}

/// PAC-native serving: a multi-worker pool of [`pacim::runtime::PacExecutor`]s
/// behind the shared dynamic batcher — no PJRT, no artifacts required.
fn serve_pac(args: &[String]) -> anyhow::Result<()> {
    use pacim::coordinator::{BatchPolicy, InferenceServer};
    use pacim::runtime::PacExecutor;

    let requests: usize = arg_value(args, "--requests")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(128);
    let clients: usize = arg_value(args, "--clients")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(8)
        .max(1);
    let workers: usize = arg_value(args, "--workers")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(2)
        .max(1);
    let batch: usize = arg_value(args, "--batch")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(8)
        .max(1);
    let wait_ms: u64 = arg_value(args, "--batch-wait-ms")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(2);
    let queue_cap: usize = arg_value(args, "--queue-cap")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(1024);

    let (model, ds, source) = serving_workload();
    // One typed front door for every serving mode: the CLI builds an
    // Engine, and the executor pool is a thin adapter over it.
    let builder = EngineBuilder::new(model).parallelism(pacim::util::Parallelism::off());
    let engine = if has_flag(args, "--exact") {
        if has_flag(args, "--dynamic") {
            eprintln!("--dynamic has no effect with --exact (fully digital baseline)");
        }
        builder.exact().build()?
    } else if has_flag(args, "--dynamic") {
        builder
            .pac(PacConfig::serving())
            .dynamic(pacim::arch::ThresholdSet::default_cifar())
            .build()?
    } else {
        builder.pac(PacConfig::serving()).build()?
    };
    let exec = PacExecutor::from_engine(engine, batch)?;
    println!(
        "serving {} ({source}, {} executor) | {workers} workers | batch {batch} | \
         {clients} clients | {requests} requests",
        exec.model().name,
        exec.engine().mode()
    );

    let server = InferenceServer::start_pool(
        move |_| Ok(exec.clone()),
        BatchPolicy {
            max_wait: std::time::Duration::from_millis(wait_ms),
            workers,
            queue_cap,
            ..BatchPolicy::default()
        },
    )?;
    let h = server.handle();
    let correct = std::sync::atomic::AtomicUsize::new(0);
    let served = std::sync::atomic::AtomicUsize::new(0);
    let shed = std::sync::atomic::AtomicUsize::new(0);
    let next = std::sync::atomic::AtomicUsize::new(0);
    let mut sample_cost = None;
    let t0 = std::time::Instant::now();
    std::thread::scope(|s| {
        let mut joins = Vec::new();
        for _ in 0..clients {
            let h = h.clone();
            let (correct, served, shed, next) = (&correct, &served, &shed, &next);
            let ds = &ds;
            joins.push(s.spawn(move || {
                use std::sync::atomic::Ordering::Relaxed;
                let mut cost = None;
                loop {
                    let i = next.fetch_add(1, Relaxed);
                    if i >= requests {
                        break cost;
                    }
                    let idx = i % ds.n;
                    let img: Vec<f32> = ds
                        .image(idx)
                        .iter()
                        .map(|&q| ds.params.dequantize(q))
                        .collect();
                    // Load-shed / dropped batches are counted, not fatal.
                    let r = match h.infer(img) {
                        Ok(r) => r,
                        Err(e) => {
                            shed.fetch_add(1, Relaxed);
                            eprintln!("request {i}: {e}");
                            continue;
                        }
                    };
                    served.fetch_add(1, Relaxed);
                    cost = cost.or(r.cost);
                    let pred = r
                        .logits
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .unwrap()
                        .0;
                    if pred == ds.label(idx) {
                        correct.fetch_add(1, Relaxed);
                    }
                }
            }));
        }
        for j in joins {
            sample_cost = sample_cost.or(j.join().unwrap());
        }
    });
    let wall = t0.elapsed();
    let metrics = server.stop();
    let served = served.load(std::sync::atomic::Ordering::Relaxed);
    let shed = shed.load(std::sync::atomic::Ordering::Relaxed);
    println!(
        "served {served}/{requests} requests in {:.1} ms ({shed} shed/dropped)",
        wall.as_secs_f64() * 1e3
    );
    println!(
        "throughput {:.1} img/s | p50 {:.0} us | p95 {:.0} us | p99 {:.0} us | mean batch {:.2}",
        served as f64 / wall.as_secs_f64(),
        metrics.latency_percentile_us(50.0),
        metrics.latency_percentile_us(95.0),
        metrics.latency_percentile_us(99.0),
        metrics.mean_batch_occupancy()
    );
    println!(
        "batches {} | padded slots {} | load-shed {} | failed {}",
        metrics.batches, metrics.padded_slots, metrics.rejected, metrics.failed_batches
    );
    for w in &metrics.per_worker {
        println!(
            "  worker {}: {} reqs in {} batches, p50 {:.0} us",
            w.worker, w.requests, w.batches, w.p50_us
        );
    }
    if let Some(c) = sample_cost {
        println!(
            "modeled PACiM cost per image: {} bit-serial cycles, {:.2} uJ",
            c.cycles,
            c.total_uj()
        );
        println!(
            "modeled activation traffic per image: {} bits ({:.1}% below 8-bit dense)",
            c.act_bits,
            c.act_traffic_reduction() * 100.0
        );
    }
    println!(
        "accuracy {:.2}%{}",
        correct.load(std::sync::atomic::Ordering::Relaxed) as f64 / served.max(1) as f64 * 100.0,
        if source == "synthetic" {
            " (random weights — accuracy is noise; latency/cost are real)"
        } else {
            ""
        }
    );
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn serve_pjrt(_args: &[String]) -> anyhow::Result<()> {
    anyhow::bail!(
        "this binary was built without the `pjrt` feature. Enabling it is \
         not just a cargo flag: the feature needs the xla-rs bindings, which \
         are not on crates.io — vendor xla-rs, add it as the `xla` dependency \
         in rust/Cargo.toml, then build with `--features pjrt` (see the \
         [features] notes in rust/Cargo.toml and README.md). The default \
         `pacim serve` (no --pjrt) runs the PAC-native executor instead"
    )
}

#[cfg(feature = "pjrt")]
fn serve_pjrt(args: &[String]) -> anyhow::Result<()> {
    use pacim::coordinator::{BatchPolicy, InferenceServer};
    use pacim::runtime::PjrtExecutor;
    let man = Manifest::load(artifacts_dir())?;
    let ds = Dataset::load(man.path("dataset")?)?;
    let requests: usize = arg_value(args, "--requests")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(64)
        .min(ds.n);
    let wait_ms: u64 = arg_value(args, "--batch-wait-ms")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(2);
    let hlo = man.path("model_pac")?;
    let (batch, in_elems, classes) = (man.batch()?, man.input_elems()?, man.classes()?);
    let server = InferenceServer::start_with(
        move || PjrtExecutor::load(&hlo, batch, in_elems, classes),
        BatchPolicy {
            max_wait: std::time::Duration::from_millis(wait_ms),
            ..BatchPolicy::default()
        },
    )?;
    let h = server.handle();
    let t0 = std::time::Instant::now();
    let mut correct = 0usize;
    std::thread::scope(|s| {
        let mut joins = Vec::new();
        for i in 0..requests {
            let h = h.clone();
            let img: Vec<f32> = ds
                .image(i)
                .iter()
                .map(|&q| ds.params.dequantize(q))
                .collect();
            let label = ds.label(i);
            joins.push(s.spawn(move || {
                let r = h.infer(img).expect("infer");
                let pred = r
                    .logits
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                (pred == label) as usize
            }));
        }
        for j in joins {
            correct += j.join().unwrap();
        }
    });
    let wall = t0.elapsed();
    let metrics = server.stop();
    println!("served {requests} requests in {:.1} ms", wall.as_secs_f64() * 1e3);
    println!(
        "throughput {:.1} img/s | p50 {:.0} us | p95 {:.0} us | p99 {:.0} us | mean batch {:.1}",
        requests as f64 / wall.as_secs_f64(),
        metrics.latency_percentile_us(50.0),
        metrics.latency_percentile_us(95.0),
        metrics.latency_percentile_us(99.0),
        metrics.mean_batch_occupancy()
    );
    println!("accuracy {:.2}%", correct as f64 / requests as f64 * 100.0);
    Ok(())
}
